"""Roofline reader: aggregates the probe artifacts (loop-corrected cost
terms) and the dry-run artifacts (memory/compile proof) into the
§Roofline table, plus the repair-layering collective-bytes comparison.
"""
from __future__ import annotations

import glob
import json
import os

ART = os.environ.get("DRYRUN_ARTIFACTS", "artifacts/dryrun")
PROBE = os.environ.get("ROOFLINE_ARTIFACTS", "artifacts/roofline")


def roofline_rows():
    rows = []
    mem = {}
    for path in glob.glob(os.path.join(ART, "*.json")):
        if path.endswith("summary.json"):
            continue
        with open(path) as f:
            res = json.load(f)
        if res.get("status") == "ok" and res.get("mesh") == "single":
            mem[(res["arch"], res["shape"])] = res["memory"][
                "per_device_total_gib"
            ]
    for path in sorted(glob.glob(os.path.join(PROBE, "*.json"))):
        with open(path) as f:
            res = json.load(f)
        tag = f"{res['arch']}/{res['shape']}"
        if res.get("status") != "ok":
            rows.append((f"roofline/{tag}", 0.0, f"status={res.get('status')}"))
            continue
        r = res["roofline"]
        rows.append(
            (
                f"roofline/{tag}",
                0.0,
                (
                    f"bottleneck={r['bottleneck']};compute={r['compute_s']:.4f}s;"
                    f"memory={r['memory_s']:.4f}s;collective={r['collective_s']:.4f}s;"
                    f"useful_flops={r['useful_flops_ratio']:.2f};"
                    f"mem_gib={mem.get((res['arch'], res['shape']), 'n/a')}"
                ),
            )
        )
    # long_500k skips for pure full-attention archs (recorded in dryrun)
    for path in sorted(glob.glob(os.path.join(ART, "*long_500k*single*.json"))):
        with open(path) as f:
            res = json.load(f)
        if res.get("status") == "skipped":
            rows.append(
                (
                    f"roofline/{res['arch']}/long_500k",
                    0.0,
                    "skipped=full-attention arch (sub-quadratic decode required)",
                )
            )
    return rows


def repair_collectives():
    """Lower the layered-repair SPMD program per code and compare the
    HLO cross-pod collective bytes against the plan's Eq.(3) accounting."""
    import subprocess
    import sys

    script = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=9'
import jax, jax.numpy as jnp, numpy as np, json
from repro.core.codes import make_code
from repro.dist.collectives import plan_to_spmd, make_spmd_repair
from repro.launch.hlo_analysis import parse_collectives
from jax.sharding import PartitionSpec as P
mesh = jax.make_mesh((3,3), ('pod','node'), axis_types=(jax.sharding.AxisType.Auto,)*2)
out = []
SUB = 1 << 20
for fam, n, k, r in [('RS',9,6,3), ('MSR',9,6,3), ('DRC',9,6,3), ('RS',9,5,3), ('DRC',9,5,3)]:
    code = make_code(fam, n, k, r)
    plan = code.repair_plan(0)
    spec = plan_to_spmd(code, plan)
    fn = jax.shard_map(make_spmd_repair(spec), mesh=mesh,
                       in_specs=P(('pod','node')), out_specs=P(('pod','node')))
    comp = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((code.n, code.alpha, SUB), jnp.uint8)).compile()
    st = parse_collectives(comp.as_text())
    cross = st.bytes_by_op.get('collective-permute', 0) / (code.alpha * SUB)
    plan_cross = plan.traffic_blocks()['cross_rack_blocks']
    out.append((f'{fam}({n},{k},{r})', cross, plan_cross))
print(json.dumps(out))
"""
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        env={**os.environ, "PYTHONPATH": "src"},
        timeout=900,
    )
    rows = []
    if proc.returncode != 0:
        return [("repair_hlo/error", 0.0, proc.stderr.strip().splitlines()[-1][:80])]
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    for label, hlo_cross, plan_cross in data:
        rows.append(
            (
                f"repair_hlo/{label}",
                0.0,
                f"hlo_cross_blocks={hlo_cross:.2f};plan_cross_blocks={plan_cross:.2f}",
            )
        )
    return rows
