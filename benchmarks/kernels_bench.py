"""GF(2^8) data-path benchmarks: Pallas kernel vs pure-jnp oracle.

These time the encode/repair hot loop (the ISA-L analogue) on this
host; on TPU the kernel's bitplane matmuls land on the MXU (see
kernels/gf_matmul.py).  `derived` reports effective MiB/s of payload.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _rate(fn, payload_bytes, repeat=3):
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        jax.block_until_ready(fn())
    dt = (time.perf_counter() - t0) / repeat
    return dt * 1e6, payload_bytes / dt / 2**20


def gf_matmul_bench():
    from repro.core.codes import make_code
    from repro.kernels.ops import gf_matmul
    from repro.kernels.ref import gf_matmul_ref

    rows = []
    rng = np.random.default_rng(0)
    for label, (fam, n, k, r) in [
        ("drc963_encode", ("DRC", 9, 6, 3)),
        ("drc953_encode", ("DRC", 9, 5, 3)),
        ("msr64_encode", ("MSR", 6, 4, 6)),
    ]:
        code = make_code(fam, n, k, r)
        ka = code.k * code.alpha
        parity = code.generator[ka:]
        payload = 1 << 20  # 1 MiB per data subsymbol row
        x = jnp.asarray(rng.integers(0, 256, size=(ka, payload), dtype=np.uint8))
        us, rate = _rate(lambda: gf_matmul(parity, x, force_kernel=True), ka * payload)
        rows.append((f"kernels/pallas_{label}", us, f"mib_s={rate:.0f}"))
        us_r, rate_r = _rate(lambda: gf_matmul_ref(jnp.asarray(parity), x), ka * payload)
        rows.append((f"kernels/ref_{label}", us_r, f"mib_s={rate_r:.0f}"))
    return rows


def flash_attention_bench():
    """Flash kernel vs pure-JAX chunked attention (interpret mode is a
    correctness path on CPU; derived reports the ratio of HLO flops both
    paths schedule on the MXU — identical by construction)."""
    import math

    from repro.kernels.flash_attention import flash_attention
    from repro.models.attention import _chunked_attention

    rng = np.random.default_rng(0)
    b, s, kvh, g, d = 1, 512, 2, 2, 64
    q = jnp.asarray(rng.standard_normal((b, s, kvh * g, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kvh, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kvh, d)), jnp.float32)
    qg = q.reshape(b, s, kvh, g, d)
    jax.block_until_ready(_chunked_attention(qg, k, v, causal=True, chunk=128))
    t0 = time.perf_counter()
    jax.block_until_ready(_chunked_attention(qg, k, v, causal=True, chunk=128))
    ref_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    jax.block_until_ready(
        flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                        interpret=True)
    )
    fl_us = (time.perf_counter() - t0) * 1e6
    return [
        ("kernels/chunked_attention_512", ref_us, "path=pure_jax"),
        ("kernels/flash_attention_512", fl_us, "path=pallas_interpret"),
    ]


def repair_plan_bench():
    """Plan construction costs (once per (code, failed-node), cached)."""
    from repro.core.codes import make_code

    rows = []
    for fam, n, k, r in [("DRC", 9, 6, 3), ("DRC", 9, 5, 3), ("MSR", 8, 4, 8)]:
        code = make_code(fam, n, k, r)
        t0 = time.perf_counter()
        for f in range(code.n):
            code.repair_plan(f)
        us = (time.perf_counter() - t0) / code.n * 1e6
        rows.append(
            (f"plans/{fam}({n},{k},{r})", us, f"alpha={code.alpha}")
        )
    return rows


def checkpoint_bench():
    """Erasure-coded checkpoint encode/restore/repair throughput."""
    from repro.train.checkpoint import encode_state, restore_state

    state = {
        "w": jnp.asarray(np.random.default_rng(0).standard_normal((1024, 1024)),
                         dtype=jnp.float32),
    }
    nbytes = 1024 * 1024 * 4
    rows = []
    t0 = time.perf_counter()
    ckpt = encode_state(state, family="DRC", n=9, k=6, r=3)
    enc = time.perf_counter() - t0
    rows.append(("checkpoint/encode_drc963", enc * 1e6, f"mib_s={nbytes/enc/2**20:.0f}"))
    t0 = time.perf_counter()
    restore_state(ckpt, state)
    dt = time.perf_counter() - t0
    rows.append(("checkpoint/restore_direct", dt * 1e6, f"mib_s={nbytes/dt/2**20:.0f}"))
    t0 = time.perf_counter()
    _, rep = restore_state(ckpt, state, available=set(range(1, 9)))
    dt = time.perf_counter() - t0
    rows.append(
        (
            "checkpoint/restore_repair",
            dt * 1e6,
            f"mib_s={nbytes/dt/2**20:.0f};cross_blocks={rep.cross_rack_blocks}",
        )
    )
    return rows
