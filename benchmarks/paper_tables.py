"""Benchmarks reproducing every table/figure of the paper.

Each function returns a list of CSV rows (name, us_per_call, derived);
`derived` carries the figure's headline quantity so the run output is
self-checking against the paper.
"""
from __future__ import annotations

import time

from repro import obs


def _timeit(fn, repeat=3):
    fn()  # warmup / construction cache
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn()
    us = (time.perf_counter() - t0) / repeat * 1e6
    return us, out


def fig3_bandwidth():
    """Fig. 3: cross-rack repair bandwidth for all code configs."""
    from repro.core.analysis.bandwidth import fig3_rows

    us, rows = _timeit(fig3_rows, repeat=1)
    out = []
    for r in rows:
        obs.gauge_set("fig3.cross_rack_blocks", r.cross_rack_blocks,
                      code=r.label)
        out.append(
            (
                f"fig3/{r.label}",
                us / len(rows),
                f"cross_rack_blocks={r.cross_rack_blocks:.3f};overhead={r.storage_overhead:.2f}x",
            )
        )
    return out


def tables12_mttdl():
    """Tables 1-2: MTTDL of flat vs hierarchical placement."""
    from repro.core.analysis.reliability import table1_rows, table2_rows

    us1, t1 = _timeit(table1_rows)
    us2, t2 = _timeit(table2_rows)
    rows = []
    for i, m in enumerate(t1["mttf_years"]):
        rows.append(
            (
                f"table1/mttf_{m}y",
                us1 / 5,
                f"flat={t1['flat_corr'][i]:.2e};hier={t1['hier_corr'][i]:.2e}",
            )
        )
    for i, g in enumerate(t2["gamma_gbps"]):
        rows.append(
            (
                f"table2/gamma_{g}gbps",
                us2 / 4,
                f"flat={t2['flat_corr'][i]:.2e};hier={t2['hier_corr'][i]:.2e}",
            )
        )
    return rows


def table3_breakdown():
    """Table 3: single-block repair time decomposition."""
    from repro.core.codes import make_code
    from repro.storage import ClusterSim

    sim = ClusterSim()
    rows = []
    for label, (n, k, r), bm in [
        ("DRC(9,6,3)", (9, 6, 3), 63.0),
        ("DRC(9,5,3)", (9, 5, 3), 64.0),
    ]:
        code = make_code("DRC", n, k, r)
        us, d = _timeit(lambda c=code, b=bm: sim.table3_breakdown(c, b))
        for stage, secs in d.items():
            obs.gauge_set("table3.stage_s", secs, code=label, stage=stage)
        derived = ";".join(f"{k2}={v:.3f}s" for k2, v in d.items())
        rows.append((f"table3/{label}", us, derived))
    return rows


def fig6_recovery():
    """Fig. 6: node-recovery throughput vs gateway bandwidth."""
    from repro.core.codes import make_code
    from repro.storage import ClusterSim

    sim = ClusterSim()
    codes = [
        ("RS", 9, 6, 3), ("MSR", 9, 6, 3), ("DRC", 9, 6, 3),
        ("RS", 9, 5, 3), ("DRC", 9, 5, 3),
        ("RS", 6, 3, 3), ("MSR", 6, 3, 3), ("DRC", 6, 3, 3),
        ("RS", 6, 4, 3), ("MSR", 6, 4, 3), ("DRC", 6, 4, 3),
        ("RS", 8, 6, 4), ("DRC", 8, 6, 4),
    ]
    rows = []
    for fam, n, k, r in codes:
        code = make_code(fam, n, k, r)
        for g in (0.2, 0.5, 1.0, 2.0):
            us, tput = _timeit(
                lambda c=code, gg=g: sim.node_recovery_throughput(c, gateway_gbps=gg)
            )
            rows.append(
                (f"fig6/{fam}({n},{k},{r})@{g}Gbps", us, f"recovery_mib_s={tput:.1f}")
            )
    return rows


def fig7_degraded_read():
    """Fig. 7: degraded read latency vs gateway bandwidth."""
    from repro.core.codes import make_code
    from repro.storage import ClusterSim

    sim = ClusterSim()
    rows = []
    for fam, n, k, r in [
        ("RS", 9, 5, 3), ("DRC", 9, 5, 3), ("RS", 9, 6, 3), ("DRC", 9, 6, 3),
        ("MSR", 6, 3, 3), ("DRC", 6, 3, 3),
    ]:
        code = make_code(fam, n, k, r)
        for g in (0.2, 0.5, 1.0, 2.0):
            us, t = _timeit(
                lambda c=code, gg=g: sim.degraded_read_time(c, gateway_gbps=gg)
            )
            rows.append(
                (f"fig7/{fam}({n},{k},{r})@{g}Gbps", us, f"degraded_read_s={t:.3f}")
            )
    return rows


def fig8_strip_block():
    """Fig. 8: strip-size and block-size sensitivity."""
    from repro.core.codes import make_code
    from repro.storage import ClusterSim

    sim = ClusterSim()
    code = make_code("DRC", 9, 5, 3)
    rows = []
    for strip in (1, 8, 64, 256, 2048, 16384):
        us, tput = _timeit(
            lambda s=strip: sim.node_recovery_throughput(code, strip_kib=s)
        )
        rows.append((f"fig8a/strip_{strip}KiB", us, f"recovery_mib_s={tput:.1f}"))
    for block in (1, 4, 16, 64, 256):
        us, tput = _timeit(
            lambda b=block: sim.node_recovery_throughput(code, block_mib=b)
        )
        rows.append((f"fig8b/block_{block}MiB", us, f"recovery_mib_s={tput:.1f}"))
    return rows
