"""Benchmark driver: one function per paper table/figure + framework
benches.  Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only fig6 fig7 ...]
                                          [--trace out/bench_trace.json]

With ``--trace PATH`` the whole run executes under a `repro.obs` tracer:
every suite gets a span, the simulator/kernel instrumentation fires, and
two artifacts are persisted next to the CSV output — ``PATH`` (Chrome
trace_event JSON for chrome://tracing) and ``PATH`` with a
``.summary.json`` suffix (aggregated spans + counters + gauges).
"""
from __future__ import annotations

import argparse
import contextlib
import os
import sys
import traceback

from repro import obs

from . import kernels_bench, paper_tables, roofline

SUITES = {
    "fig3": paper_tables.fig3_bandwidth,
    "tables12": paper_tables.tables12_mttdl,
    "table3": paper_tables.table3_breakdown,
    "fig6": paper_tables.fig6_recovery,
    "fig7": paper_tables.fig7_degraded_read,
    "fig8": paper_tables.fig8_strip_block,
    "kernels": kernels_bench.gf_matmul_bench,
    "flash": kernels_bench.flash_attention_bench,
    "plans": kernels_bench.repair_plan_bench,
    "checkpoint": kernels_bench.checkpoint_bench,
    "roofline": roofline.roofline_rows,
    "repair_hlo": roofline.repair_collectives,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="+", default=None, choices=list(SUITES))
    ap.add_argument(
        "--trace", metavar="PATH", default=None,
        help="run under a repro.obs tracer; write Chrome trace JSON to PATH "
             "and an aggregated summary to PATH's .summary.json sibling",
    )
    args = ap.parse_args(argv)
    names = args.only or list(SUITES)
    print("name,us_per_call,derived")
    failed = []
    ctx = obs.tracing("benchmarks") if args.trace else contextlib.nullcontext()
    with ctx as tracer:
        for name in names:
            try:
                with obs.span(f"suite.{name}", cat="bench"):
                    for row, us, derived in SUITES[name]():
                        print(f"{row},{us:.1f},{derived}")
            except Exception:  # keep the suite running; report at the end
                failed.append(name)
                traceback.print_exc(file=sys.stderr)
    if args.trace:
        d = os.path.dirname(args.trace)
        if d:
            os.makedirs(d, exist_ok=True)
        obs.write_chrome_trace(tracer, args.trace)
        stem, _ = os.path.splitext(args.trace)
        obs.write_summary(tracer, stem + ".summary.json")
        print(f"# trace: {args.trace}  summary: {stem}.summary.json",
              file=sys.stderr)
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
