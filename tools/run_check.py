"""CLI for ``repro.check``: plan verification sweep + AST lint.

Usage (from the repo root; ``src`` is added to ``sys.path`` automatically)::

    python -m tools.run_check                  # full gate: sweep + lint
    python -m tools.run_check --json out.json  # also write the report
    python -m tools.run_check --plans-only
    python -m tools.run_check --ast-only
    python -m tools.run_check --self-test      # mutation test: corrupted
                                               # plans must FAIL with the
                                               # owning rule id

Exit code 0 iff nothing FAILed (WARNs are reported but do not gate).
This is the CI ``check`` job's entry point.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT / "src") not in sys.path:
    sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.check.ast_rules import lint_tree  # noqa: E402
from repro.check.plan import self_test, sweep_report  # noqa: E402
from repro.check.report import FAIL, WARN, CheckReport  # noqa: E402


def _print_plan_summary(report: CheckReport) -> None:
    by_label: dict[str, list[str]] = {}
    for rec in report.plan_records:
        by_label.setdefault(f"{rec.family:<10} {rec.label}", []).append(
            rec.status
        )
    print(f"{'family':<10} {'code':<14} {'plans':>5}  status")
    for label, statuses in sorted(by_label.items()):
        worst = FAIL if FAIL in statuses else (WARN if WARN in statuses else "PASS")
        print(f"{label:<25} {len(statuses):>5}  {worst}")


def _print_failures(report: CheckReport) -> None:
    for rec in (*report.plan_records, *report.lint_records):
        for f in rec.findings:
            if f.severity in (FAIL, WARN):
                where = getattr(rec, "label", None) or getattr(rec, "path", "")
                failed = getattr(rec, "failed", None)
                loc = f"{where}" + (f" failed={failed}" if failed is not None else "")
                print(f"  {f.severity} {f.rule} [{loc}] {f.message}")


def run_self_test() -> int:
    print("mutation self-test: corrupted plans must FAIL with the owning rule")
    results = self_test()
    ok = True
    for mutation, owner, caught in results:
        mark = "caught" if caught else "MISSED"
        print(f"  {mutation:<26} -> {owner:<32} {mark}")
        ok &= caught
    if not ok:
        print("SELF-TEST FAILED: a deliberate defect went undetected")
        return 1
    print(f"self-test OK: {len(results)}/{len(results)} mutations caught")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="tools.run_check",
        description="Static verification: repair-plan sweep + AST lint.",
    )
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the machine-readable report here")
    ap.add_argument("--plans-only", action="store_true",
                    help="skip the AST lint")
    ap.add_argument("--ast-only", action="store_true",
                    help="skip the plan sweep")
    ap.add_argument("--lint-root", default=str(REPO_ROOT / "src" / "repro"),
                    help="source tree to lint (default: src/repro)")
    ap.add_argument("--self-test", action="store_true",
                    help="run the mutation self-test and exit")
    args = ap.parse_args(argv)

    if args.self_test:
        return run_self_test()

    report = CheckReport()
    if not args.ast_only:
        print("plan verifier: registry sweep (all families x shapes x "
              "failed nodes)")
        report.plan_records = sweep_report().plan_records
        _print_plan_summary(report)
    if not args.plans_only:
        print(f"AST lint: {args.lint_root}")
        report.lint_records = lint_tree(args.lint_root)
        flagged = sum(len(r.findings) for r in report.lint_records)
        print(f"  {len(report.lint_records)} files, {flagged} finding(s)")

    counts = report.counts()
    print(f"records: {counts['PASS']} PASS / {counts['WARN']} WARN / "
          f"{counts['FAIL']} FAIL")
    _print_failures(report)
    if args.json:
        report.write_json(args.json)
        print(f"report -> {args.json}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
