"""CLI for ``repro.check``: plan sweep + lowered + traced analysis + lint.

Usage (from the repo root; ``src`` is added to ``sys.path`` automatically)::

    python -m tools.run_check                  # full gate: all four layers
    python -m tools.run_check --json out.json  # also write the report
    python -m tools.run_check --plans-only
    python -m tools.run_check --lowered-only   # SPMD/shard/Pallas analyzers
    python -m tools.run_check --traced-only    # jaxpr/HLO dataflow analyzers
    python -m tools.run_check --ast-only
    python -m tools.run_check --strict-warnings  # WARNs also exit nonzero
    python -m tools.run_check --baseline tools/lowered_baseline.json
    python -m tools.run_check --baseline tools/traced_baseline.json
    python -m tools.run_check --self-test      # mutation test: corrupted
                                               # artifacts must FAIL with
                                               # the owning rule id

Exit code 0 iff nothing FAILed; with ``--strict-warnings`` a WARN-only
run exits 1 too.  ``--baseline`` fails the run if the lowered/traced
sweep produced fewer records than the committed floor (a shrinking sweep
means a family or entry point silently fell out of coverage).  This is
the CI ``check`` job's entry point.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

# The traced layer captures shard_map programs over a (pod, node) mesh;
# on the CPU host platform XLA exposes one device unless told otherwise.
# Must happen before jax initializes its backend — keep 16 in sync with
# repro.check.traced.MAX_DEVICES.
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=16"
    ).strip()

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT / "src") not in sys.path:
    sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.check.ast_rules import lint_tree  # noqa: E402
from repro.check.lowered import (  # noqa: E402
    run_lowered_sweep,
    self_test_lowered,
)
from repro.check.plan import self_test, sweep_report  # noqa: E402
from repro.check.report import FAIL, WARN, CheckReport  # noqa: E402


def _print_plan_summary(report: CheckReport) -> None:
    by_label: dict[str, list[str]] = {}
    for rec in report.plan_records:
        by_label.setdefault(f"{rec.family:<10} {rec.label}", []).append(
            rec.status
        )
    print(f"{'family':<10} {'code':<14} {'plans':>5}  status")
    for label, statuses in sorted(by_label.items()):
        worst = FAIL if FAIL in statuses else (WARN if WARN in statuses else "PASS")
        print(f"{label:<25} {len(statuses):>5}  {worst}")


def _print_lowered_summary(report: CheckReport) -> None:
    by_family: dict[str, list[str]] = {}
    for rec in report.lowered_records:
        by_family.setdefault(rec.family, []).append(rec.status)
    print(f"{'lowered family':<16} {'records':>7}  status")
    for family, statuses in sorted(by_family.items()):
        worst = FAIL if FAIL in statuses else (WARN if WARN in statuses else "PASS")
        print(f"{family:<16} {len(statuses):>7}  {worst}")


def _print_traced_summary(report: CheckReport) -> None:
    by_kind: dict[str, list[str]] = {}
    for rec in report.traced_records:
        by_kind.setdefault(rec.kind, []).append(rec.status)
    print(f"{'traced kind':<16} {'records':>7}  status")
    for kind, statuses in sorted(by_kind.items()):
        worst = FAIL if FAIL in statuses else (WARN if WARN in statuses else "PASS")
        print(f"{kind:<16} {len(statuses):>7}  {worst}")


def _print_failures(report: CheckReport) -> None:
    for rec in (
        *report.plan_records, *report.lowered_records,
        *report.traced_records, *report.lint_records,
    ):
        for f in rec.findings:
            if f.severity in (FAIL, WARN):
                where = getattr(rec, "label", None) or getattr(rec, "path", "")
                failed = getattr(rec, "failed", None)
                loc = f"{where}" + (f" failed={failed}" if failed is not None else "")
                print(f"  {f.severity} {f.rule} [{loc}] {f.message}")


def run_self_test() -> int:
    print("mutation self-test: corrupted plans must FAIL with the owning rule")
    results = self_test()
    ok = True
    for mutation, owner, caught in results:
        mark = "caught" if caught else "MISSED"
        print(f"  {mutation:<26} -> {owner:<36} {mark}")
        ok &= caught
    print("lowered self-test: corrupted lowered artifacts must FAIL with "
          "exactly the owning rule")
    lowered = self_test_lowered()
    for mutation, owner, caught, exclusive in lowered:
        if not caught:
            mark = "MISSED"
        elif not exclusive:
            mark = "NOT-EXCLUSIVE"
        else:
            mark = "caught"
        print(f"  {mutation:<26} -> {owner:<36} {mark}")
        ok &= caught and exclusive
    print("traced self-test: corrupted traced programs must FAIL with "
          "exactly the owning rule")
    from repro.check.traced import self_test_traced

    traced = self_test_traced()
    for mutation, owner, caught, exclusive in traced:
        if not caught:
            mark = "MISSED"
        elif not exclusive:
            mark = "NOT-EXCLUSIVE"
        else:
            mark = "caught"
        print(f"  {mutation:<26} -> {owner:<36} {mark}")
        ok &= caught and exclusive
    total = len(results) + len(lowered) + len(traced)
    if not ok:
        print("SELF-TEST FAILED: a deliberate defect went undetected "
              "(or was caught by the wrong rule)")
        return 1
    print(f"self-test OK: {total}/{total} mutations caught "
          f"({len(lowered)} lowered-layer + {len(traced)} traced-layer, "
          f"each by exactly its owner)")
    return 0


def _check_baseline(
    report: CheckReport, path: str, *, lowered_ran: bool, traced_ran: bool
) -> int:
    """0 iff every swept layer is at least as wide as the committed floor."""
    with open(path) as f:
        baseline = json.load(f)
    gates = []
    if "min_lowered_records" in baseline and lowered_ran:
        gates.append(("lowered", int(baseline["min_lowered_records"]),
                      len(report.lowered_records)))
    if "min_traced_records" in baseline and traced_ran:
        gates.append(("traced", int(baseline["min_traced_records"]),
                      len(report.traced_records)))
    rc = 0
    for layer, floor, got in gates:
        if got < floor:
            print(f"BASELINE REGRESSION: {layer} sweep produced {got} "
                  f"record(s), committed floor is {floor} ({path}) — "
                  f"coverage silently shrank")
            rc = 1
        else:
            print(f"baseline OK: {got} {layer} record(s) >= floor {floor}")
    if not gates:
        print(f"baseline {path} has no floor for the layers that ran")
    return rc


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="tools.run_check",
        description="Static verification: plan sweep + lowered-layer "
                    "analysis + AST lint.",
    )
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the machine-readable report here")
    ap.add_argument("--plans-only", action="store_true",
                    help="run only the plan sweep")
    ap.add_argument("--lowered-only", action="store_true",
                    help="run only the lowered-layer analyzers")
    ap.add_argument("--traced-only", action="store_true",
                    help="run only the traced-layer (jaxpr/HLO) analyzers")
    ap.add_argument("--ast-only", action="store_true",
                    help="run only the AST lint")
    ap.add_argument("--lint-root", default=str(REPO_ROOT / "src" / "repro"),
                    help="source tree to lint (default: src/repro)")
    ap.add_argument("--strict-warnings", action="store_true",
                    help="exit nonzero when any record WARNs, not just FAILs")
    ap.add_argument("--baseline", metavar="PATH", default=None,
                    help="JSON file with min_lowered_records and/or "
                         "min_traced_records; fail if a sweep shrinks "
                         "below its floor")
    ap.add_argument("--self-test", action="store_true",
                    help="run the mutation self-tests and exit")
    args = ap.parse_args(argv)

    if args.self_test:
        return run_self_test()

    only_flags = [
        args.plans_only, args.lowered_only, args.traced_only, args.ast_only
    ]
    if sum(only_flags) > 1:
        ap.error("--plans-only/--lowered-only/--traced-only/--ast-only "
                 "are exclusive")
    run_all = not any(only_flags)

    report = CheckReport()
    if run_all or args.plans_only:
        print("plan verifier: registry sweep (all families x shapes x "
              "failed nodes)")
        report.plan_records = sweep_report().plan_records
        _print_plan_summary(report)
    if run_all or args.lowered_only:
        print("lowered-layer analysis: SPMD schedules, sharding rules, "
              "Pallas kernel geometry")
        report.lowered_records = run_lowered_sweep()
        _print_lowered_summary(report)
    if run_all or args.traced_only:
        print("traced-layer analysis: jaxpr/HLO dataflow over the compiled "
              "repair, kernel, serve and train programs")
        from repro.check.traced import run_traced_sweep

        report.traced_records = run_traced_sweep()
        _print_traced_summary(report)
    if run_all or args.ast_only:
        print(f"AST lint: {args.lint_root}")
        report.lint_records = lint_tree(args.lint_root)
        flagged = sum(len(r.findings) for r in report.lint_records)
        print(f"  {len(report.lint_records)} files, {flagged} finding(s)")

    counts = report.counts()
    print(f"records: {counts['PASS']} PASS / {counts['WARN']} WARN / "
          f"{counts['FAIL']} FAIL")
    _print_failures(report)
    if args.json:
        report.write_json(args.json)
        print(f"report -> {args.json}")
    rc = 0 if report.ok else 1
    if args.baseline and (run_all or args.lowered_only or args.traced_only):
        rc = max(rc, _check_baseline(
            report, args.baseline,
            lowered_ran=run_all or args.lowered_only,
            traced_ran=run_all or args.traced_only,
        ))
    if rc == 0 and args.strict_warnings and counts[WARN] > 0:
        print(f"--strict-warnings: {counts[WARN]} WARN record(s) gate the "
              f"run")
        rc = 1
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
