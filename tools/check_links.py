#!/usr/bin/env python3
"""Docs sanity: every relative link/path reference in the Markdown docs
must resolve to a real file in the repo.

Checks README.md, ROADMAP.md and docs/**/*.md:

* inline links ``[text](target)`` — external (``http``/``https``/
  ``mailto``) targets are skipped, ``#fragment`` suffixes are stripped;
* backtick path references like ``src/repro/obs/tracer.py`` (anything
  that looks like a repo-relative path with a file extension).

Exit 0 when everything resolves, 1 with a report otherwise.

Run:  python tools/check_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOCS = [REPO / "README.md", REPO / "ROADMAP.md",
        *sorted((REPO / "docs").glob("**/*.md"))]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
PATH_RE = re.compile(r"`((?:src|docs|tests|benchmarks|examples|tools)"
                     r"/[\w./-]+\.\w{1,4})`")


def check(doc: Path) -> list[str]:
    errors = []
    text = doc.read_text()
    targets: set[str] = set()
    for m in LINK_RE.finditer(text):
        t = m.group(1)
        if t.startswith(("http://", "https://", "mailto:", "#")):
            continue
        targets.add(t.split("#", 1)[0])
    targets.update(m.group(1) for m in PATH_RE.finditer(text))
    for t in sorted(targets):
        if not t:
            continue
        if not (doc.parent / t).exists() and not (REPO / t).exists():
            errors.append(f"{doc.relative_to(REPO)}: broken reference {t!r}")
    return errors


def main() -> int:
    errors: list[str] = []
    for doc in DOCS:
        if doc.exists():
            errors.extend(check(doc))
    if errors:
        print("\n".join(errors), file=sys.stderr)
        return 1
    print(f"checked {len(DOCS)} docs: all relative references resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
