"""Repo tooling: link checker, static-verification CLI (`run_check`)."""
