"""Tests for `repro.check.lowered` — the lowered-layer static analyzer.

Three families (SPMD schedule, sharding rules, Pallas kernels), each
with: the full sweep PASSing on the real artifacts, every mutation
caught by *exactly* its owning rule, and targeted unit checks of the
trickier rule semantics.
"""
import dataclasses

import numpy as np
import pytest

from repro.check.lowered import (
    LOWERED_MUTATIONS,
    LOWERED_RULES,
    PALLAS_FAMILY,
    SHARD_FAMILY,
    SPMD_FAMILY,
    fail_rules,
    pallas,
    run_lowered_sweep,
    self_test_lowered,
    shard_rules,
    spmd,
)
from repro.check.report import FAIL, PASS
from repro.core.codes import make_code
from repro.dist.collectives import plan_to_spmd
from repro.dist.sharding import MODES, make_rules, resolve_spec
from repro.kernels.gf_matmul import gf_matmul_geometry

_CODES: dict = {}


def get_code(family, n, k, r):
    key = (family, n, k, r)
    if key not in _CODES:
        _CODES[key] = make_code(family, n, k, r)
    return _CODES[key]


def get_lowering(family, n, k, r, failed=0):
    code = get_code(family, n, k, r)
    plan = code.repair_plan(failed)
    return code, plan, plan_to_spmd(code, plan)


# ----------------------------------------------------------------- registry


def test_rule_registry_namespacing_and_families():
    for rule_id, (family, _fn) in LOWERED_RULES.items():
        assert rule_id.startswith("lowered."), rule_id
        assert family in (SPMD_FAMILY, SHARD_FAMILY, PALLAS_FAMILY)
    assert len(LOWERED_RULES) >= 12


def test_every_rule_owns_at_least_one_mutation_family():
    owned = {owner for _family, owner in LOWERED_MUTATIONS.values()}
    # every registered rule is exercised by some mutation
    assert owned == set(LOWERED_RULES), (
        set(LOWERED_RULES) - owned, owned - set(LOWERED_RULES)
    )


# -------------------------------------------------------------- full sweep


def test_lowered_sweep_all_pass_and_covers_all_families():
    records = run_lowered_sweep()
    assert len(records) >= 100
    assert {r.family for r in records} == {
        SPMD_FAMILY, SHARD_FAMILY, PALLAS_FAMILY
    }
    bad = [r for r in records if r.status != PASS]
    assert bad == [], [
        (r.label, r.artifact, [f.message for f in r.findings]) for r in bad
    ]


@pytest.mark.parametrize("mutation", sorted(LOWERED_MUTATIONS))
def test_mutation_caught_by_exactly_owning_rule(mutation):
    rows = {m: (owner, caught, exclusive)
            for m, owner, caught, exclusive in self_test_lowered()}
    owner, caught, exclusive = rows[mutation]
    assert caught, f"{mutation} not caught by {owner}"
    assert exclusive, f"{mutation} caught by more than just {owner}"


# ------------------------------------------------------------ SPMD schedule


@pytest.mark.parametrize("shape", [
    ("DRC", 6, 4, 3), ("DRC", 8, 6, 4), ("RS", 9, 6, 3),
])
def test_spmd_real_lowerings_pass_every_rule(shape):
    fam, n, k, r = shape
    code = get_code(fam, n, k, r)
    for rec in spmd.verify_spmd_lowering(code):
        assert rec.status == PASS, (
            rec.artifact, [f.message for f in rec.findings]
        )


def test_spmd_self_send_finding_names_the_pod():
    code, plan, spec = get_lowering("DRC", 6, 4, 3)
    mutated = spmd.mutate_spmd(code, plan, spec, "spmd_self_send")
    findings = spmd.check_permute_partial(code, plan, mutated)
    assert findings and findings[0].severity == FAIL
    assert findings[0].witness["pod"] == spec.target_pod


def test_spmd_in_bounds_padding_row_is_caught():
    """A scheduled row can be in bounds yet point at the zero padding of
    the stacked relayer matrices — a bounds check alone misses it."""
    code, plan, spec = get_lowering("DRC", 6, 4, 3)
    assert spec.ru > 0
    rel_units = spmd._relayer_units(plan)
    padding = None
    for q in range(spec.r):
        if q == spec.target_pod or not spec.cross_idx[q]:
            continue
        for slot in range(spec.w):
            node = q * spec.w + slot
            have = rel_units.get(node, 0)
            if have < spec.ru:  # first padding offset of this node
                padding = (q, spec.w * spec.nu + slot * spec.ru + have)
                break
        if padding:
            break
    assert padding is not None, "no padding row in this lowering"
    q, row = padding
    assert 0 <= row < spec.pool_rows  # in bounds — that's the point
    cross = list(spec.cross_idx)
    cross[q] = (row, *cross[q][1:])  # swap, preserving per-pod counts
    mutated = dataclasses.replace(spec, cross_idx=tuple(cross))
    assert fail_rules(
        spmd.analyze_spmd_spec(code, plan, mutated)
    ) == {spmd.R_LS_ROWS}


def test_spmd_byte_accounting_matches_traffic_blocks():
    code, plan, spec = get_lowering("DRC", 9, 6, 3)
    t = plan.traffic_blocks()
    scheduled = sum(
        len(rows) for q, _dst, rows in spec.permute_steps()
        if q != spec.target_pod
    )
    assert scheduled == round(float(t["cross_rack_blocks"]) * plan.alpha)
    assert spmd.check_byte_accounting(code, plan, spec) == []


def test_spmd_rotation_balance_detects_stuck_rotation():
    code, plan, spec = get_lowering("DRC", 6, 4, 3)
    stuck = spmd.mutate_spmd(code, plan, spec, "spmd_stuck_rotation")
    findings = spmd.check_rotation_balance(code, plan.failed, stuck)
    assert findings, "stuck rotation not flagged"
    assert all(f.rule == spmd.R_LS_ROTATION for f in findings)
    # the real rotation cycle is balanced
    good = spmd.rotation_specs(code, plan.failed)
    assert spmd.check_rotation_balance(code, plan.failed, good) == []


# ------------------------------------------------------------ shard rules


@pytest.mark.parametrize("mode", sorted(MODES))
def test_shard_tables_pass_for_every_mode(mode):
    from repro.configs import get_config

    rec = shard_rules.verify_shard_rules(get_config("minicpm_2b"), mode)
    assert rec.status == PASS, [f.message for f in rec.findings]


def test_shard_prime_dimension_must_replicate():
    from repro.configs import get_config

    art = shard_rules.ShardArtifact(
        rules=make_rules("tp"),
        config=get_config("minicpm_2b"),
        meshes=shard_rules.CANONICAL_MESHES,
        resolver=resolve_spec,
    )
    assert shard_rules.check_divisibility(art) == []
    # the greedy resolver shards the prime probe -> caught
    bad = shard_rules.mutate_shard(art, "shard_greedy_resolver")
    findings = shard_rules.check_divisibility(bad)
    assert any("fallback unreachable" in f.message or "does not divide"
               in f.message for f in findings)


def test_shard_pod_leak_message_explains_repair_cost():
    from repro.configs import get_config

    art = shard_rules.ShardArtifact(
        rules=make_rules("tp", multi_pod=True),
        config=get_config("minicpm_2b"),
        meshes=shard_rules.MULTI_POD_MESHES,
        resolver=resolve_spec,
    )
    bad = shard_rules.mutate_shard(art, "shard_pod_leak")
    findings = shard_rules.check_multi_pod(bad)
    assert findings and findings[0].witness["logical"] == "embed"


# ----------------------------------------------------------- pallas kernels


@pytest.mark.parametrize("shape", list(pallas.GEOMETRY_SHAPES))
def test_kernel_geometry_in_bounds_and_write_disjoint(shape):
    geom = gf_matmul_geometry(*shape)
    assert pallas.analyze_geometry(geom) == []


def test_kernel_geometry_is_what_pallas_call_consumes():
    """The verifier sweeps the same object the kernel builds specs from."""
    geom = gf_matmul_geometry(3, 6, 4096, 512)
    assert geom.grid == (8,)
    in_specs = geom.in_specs()
    assert len(in_specs) == 2
    assert geom.out_spec().block_shape == (3, 512)


def test_kernel_geometry_rejects_indivisible_payload():
    with pytest.raises(ValueError, match="not a multiple"):
        gf_matmul_geometry(3, 6, 1000, 512)


def test_pallas_oob_witness_names_grid_point_and_extent():
    geom = gf_matmul_geometry(2, 4, 1024, 256)
    bad = dataclasses.replace(
        geom,
        in_index_maps=(geom.in_index_maps[0], lambda j: (0, j + 1)),
    )
    findings = pallas.check_pallas_oob(bad)
    assert findings and findings[0].severity == FAIL
    assert findings[0].witness["extent"] == 1024


def test_pallas_alias_detects_constant_out_map():
    geom = gf_matmul_geometry(2, 4, 1024, 256)
    bad = dataclasses.replace(geom, out_index_map=lambda j: (0, 0))
    findings = pallas.check_pallas_out_alias(bad)
    assert findings and "write-write race" in findings[0].message


def test_gf_dtype_pass_clean_on_real_kernels():
    for path in pallas.kernel_source_paths():
        with open(path) as f:
            assert pallas.check_gf_dtype(path, f.read()) == [], path


def test_gf_dtype_flags_uint8_addition():
    src = (
        "def _k(x_ref, o_ref):\n"
        "    a = x_ref[...]\n"
        "    o_ref[...] = a + a\n"  # GF addition is XOR, not +
    )
    findings = pallas.check_gf_dtype("k.py", src)
    assert [f.rule for f in findings] == [pallas.R_PL_DTYPE]


def test_gf_dtype_explicit_cast_clears_taint():
    src = (
        "import jax.numpy as jnp\n"
        "def _k(x_ref, o_ref):\n"
        "    a = x_ref[...].astype(jnp.int32)\n"
        "    o_ref[...] = a + a\n"
    )
    assert pallas.check_gf_dtype("k.py", src) == []


def test_gf_dtype_flags_reduction_without_dtype():
    src = (
        "import jax.numpy as jnp\n"
        "def _k(x_ref, o_ref):\n"
        "    o_ref[...] = jnp.sum(x_ref[...], axis=0)\n"
    )
    findings = pallas.check_gf_dtype("k.py", src)
    assert findings and "wraps mod 256" in findings[0].message


def test_gf_dtype_flags_matmul_without_preferred_type():
    src = (
        "import jax\n"
        "def _k(a, b):\n"
        "    return jax.lax.dot_general(a, b, dimension_numbers=None)\n"
    )
    findings = pallas.check_gf_dtype("k.py", src)
    assert findings and "preferred_element_type" in findings[0].message


# ------------------------------------------------------------- report model


def test_lowered_record_json_roundtrip(tmp_path):
    import json

    from repro.check.report import CheckReport

    code = get_code("DRC", 6, 4, 3)
    report = CheckReport(lowered_records=spmd.verify_spmd_lowering(code))
    path = report.write_json(str(tmp_path / "lowered.json"))
    with open(path) as f:
        obj = json.load(f)
    assert obj["version"] == 3
    rec = obj["lowered_records"][0]
    assert {"label", "family", "artifact", "status", "findings",
            "info"} <= set(rec)
    assert rec["family"] == SPMD_FAMILY
    assert obj["summary"]["FAIL"] == 0


def test_mutations_do_not_touch_the_original_spec():
    code, plan, spec = get_lowering("DRC", 6, 4, 3)
    before = (
        tuple(tuple(r) for r in spec.cross_idx),
        np.asarray(spec.node_mats).copy(),
        tuple(spec.target_idx),
    )
    for mutation, (family, _owner) in LOWERED_MUTATIONS.items():
        if family != SPMD_FAMILY:
            continue
        spmd.mutate_spmd(code, plan, spec, mutation)
    assert tuple(tuple(r) for r in spec.cross_idx) == before[0]
    np.testing.assert_array_equal(np.asarray(spec.node_mats), before[1])
    assert tuple(spec.target_idx) == before[2]


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
