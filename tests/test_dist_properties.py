"""Property tests for repro.dist.sharding rule resolution.

Invariants (hypothesis-driven over modes, meshes, and shapes):
* a resolved spec never uses the same mesh axis twice;
* every sharded dimension divides evenly by the product of the mesh
  axis sizes it shards over.
"""
import jax
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.dist.sharding import MODES, make_rules, resolve_spec
from repro.models.common import LOGICAL


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH_SHAPES = (
    {"data": 4, "model": 8},
    {"pod": 2, "data": 4, "model": 4},
    {"data": 16, "model": 16},
    {"data": 3, "model": 5},
    {"data": 1, "model": 4},
)
DIM_SIZES = (1, 2, 3, 8, 15, 24, 64, 240)


@settings(max_examples=200, deadline=None)
@given(
    mode=st.sampled_from(MODES),
    multi_pod=st.booleans(),
    mesh_shape=st.sampled_from(MESH_SHAPES),
    dims=st.lists(
        st.tuples(st.sampled_from(LOGICAL + (None,)), st.sampled_from(DIM_SIZES)),
        min_size=1,
        max_size=4,
    ),
)
def test_resolved_spec_invariants(mode, multi_pod, mesh_shape, dims):
    names = tuple(name for name, _ in dims)
    shape = tuple(size for _, size in dims)
    rules = make_rules(mode, multi_pod=multi_pod)
    spec = resolve_spec(names, shape, FakeMesh(mesh_shape), rules)

    assert isinstance(spec, jax.sharding.PartitionSpec)
    assert len(spec) == len(dims)
    used = []
    for entry, (name, size) in zip(spec, dims):
        if entry is None:
            continue
        assert name is not None  # None dims must stay unsharded
        group = entry if isinstance(entry, tuple) else (entry,)
        used.extend(group)
        divisor = 1
        for axis in group:
            assert axis in mesh_shape  # never invents a mesh axis
            assert axis in rules.mesh_axes(name)  # only rule candidates
            divisor *= mesh_shape[axis]
        assert divisor > 1  # size-1 axes are skipped, not recorded
        assert size % divisor == 0  # even divisibility
    assert len(used) == len(set(used))  # no mesh axis used twice


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
