"""Traced-layer verification tests (``repro.check.traced``).

Mesh-shaped captures (shard_map over the (pod, node) grid) run in
subprocesses so the XLA host-device-count override applies before jax
initializes its backend; the pure collective-pairing matcher, the dtype
taint lattice on mesh-free programs, the HLO permute parser, and the new
AST lint rule run in-process.  Property tests additionally want
hypothesis and are skipped without it.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - env without hypothesis
    HAVE_HYPOTHESIS = False


def run_sub(code: str, devices: int = 16, timeout=600) -> str:
    env = {
        **os.environ,
        "PYTHONPATH": os.path.join(REPO, "src"),
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
    }
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
        cwd=REPO,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    return proc.stdout


# ------------------------------------------------- pairing matcher (pure)


def _permute(pairs, rows):
    from repro.check.traced.capture import PermuteOp

    return PermuteOp(
        axes=("pod",), pairs=tuple(pairs), rows=rows,
        nbytes=rows * 256, dtype="uint8",
    )


def test_validate_pairs_accepts_wellformed():
    from repro.check.traced.collectives import validate_pairs

    assert validate_pairs(((0, 1), (1, 2), (2, 0)), r=3) == []


def test_validate_pairs_defects():
    from repro.check.traced.collectives import validate_pairs

    assert any("empty" in d for d in validate_pairs((), r=3))
    assert any("outside" in d for d in validate_pairs(((0, 3),), r=3))
    assert any("self-send" in d for d in validate_pairs(((1, 1),), r=3))
    assert any("duplicate source" in d
               for d in validate_pairs(((0, 1), (0, 2)), r=3))
    assert any("duplicate destination" in d
               for d in validate_pairs(((0, 2), (1, 2)), r=3))


def test_match_permutes_complete():
    from repro.check.traced.collectives import match_permutes

    steps = ((1, 0, (4, 5)), (2, 0, (6,)))
    permutes = (_permute([(1, 0)], 2), _permute([(2, 0)], 1))
    m = match_permutes(permutes, steps)
    assert m.complete
    assert sorted(m.matched) == [(0, 0), (1, 1)]


def test_match_permutes_orphans_both_ways():
    from repro.check.traced.collectives import match_permutes

    steps = ((1, 0, (4,)), (2, 0, (5,)))
    # one declared step never traced, one traced permute never declared
    m = match_permutes((_permute([(1, 0)], 1), _permute([(2, 1)], 1)), steps)
    assert not m.complete
    assert m.orphan_permutes == (1,)
    assert m.orphan_steps == (1,)


def test_match_permutes_duplicate_consumes_step_once():
    from repro.check.traced.collectives import match_permutes

    steps = ((1, 0, (4,)),)
    m = match_permutes((_permute([(1, 0)], 1), _permute([(1, 0)], 1)), steps)
    assert m.matched == ((0, 0),)
    assert m.orphan_permutes == (1,)


if HAVE_HYPOTHESIS:

    @st.composite
    def schedules(draw):
        """A random DoubleR-like schedule: distinct non-target source
        pods each shipping a distinct nonempty row set to the target."""
        r = draw(st.integers(min_value=2, max_value=6))
        target = draw(st.integers(min_value=0, max_value=r - 1))
        srcs = draw(
            st.lists(
                st.integers(min_value=0, max_value=r - 1).filter(
                    lambda p: p != target
                ),
                unique=True, min_size=1, max_size=r - 1,
            )
        )
        steps = tuple(
            (s, target, tuple(range(draw(st.integers(1, 5)))))
            for s in srcs
        )
        return r, target, steps

    @settings(max_examples=60, deadline=None)
    @given(schedules())
    def test_property_faithful_trace_matches_completely(sched):
        from repro.check.traced.collectives import (
            match_permutes, validate_pairs,
        )

        r, _target, steps = sched
        permutes = tuple(
            _permute([(s, d)], len(rows)) for s, d, rows in steps
        )
        for p in permutes:
            assert validate_pairs(p.pairs, r) == []
        assert match_permutes(permutes, steps).complete

    @settings(max_examples=60, deadline=None)
    @given(schedules(), st.data())
    def test_property_dropped_permute_is_exactly_one_orphan_step(sched, data):
        from repro.check.traced.collectives import match_permutes

        _r, _target, steps = sched
        drop = data.draw(st.integers(0, len(steps) - 1))
        permutes = tuple(
            _permute([(s, d)], len(rows))
            for i, (s, d, rows) in enumerate(steps)
            if i != drop
        )
        m = match_permutes(permutes, steps)
        assert m.orphan_permutes == ()
        assert m.orphan_steps == (drop,)

    @settings(max_examples=60, deadline=None)
    @given(schedules())
    def test_property_foreign_permute_is_orphan(sched):
        from repro.check.traced.collectives import match_permutes

        r, target, steps = sched
        # a permute between two pods that matches no declared step:
        # same endpoints as step 0 but wrong row count
        s, d, rows = steps[0]
        permutes = tuple(
            _permute([(ps, pd)], len(prow)) for ps, pd, prow in steps
        ) + (_permute([(s, d)], len(rows) + 1),)
        m = match_permutes(permutes, steps)
        assert m.orphan_permutes == (len(steps),)
        assert m.orphan_steps == ()

else:  # keep the skip visible in test output rather than silently absent

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_traced_properties_skipped():
        pass


# --------------------------------------------- dtype lattice (mesh-free)


def test_dtype_mutants_each_fail_their_owner():
    from repro.check.report import FAIL
    from repro.check.traced.dtype_flow import (
        DTYPE_MUTATIONS, dtype_mutation_findings,
    )

    for mutation, owner in DTYPE_MUTATIONS.items():
        fails = {
            f.rule for f in dtype_mutation_findings(mutation)
            if f.severity == FAIL
        }
        assert fails == {owner}, (mutation, fails)


def test_gf_matmul_jnp_is_taint_clean():
    from repro.check.traced.capture import capture_gf_ref
    from repro.check.traced.dtype_flow import dtype_flow_violations

    assert dtype_flow_violations(capture_gf_ref()) == []


# ------------------------------------------------- HLO permute parsing


_HLO = """\
ENTRY %main {
  %p0 = u8[6,2,256]{2,1,0} parameter(0)
  %collective-permute.1 = u8[2,256]{1,0} collective-permute(u8[2,256]{1,0} %fusion), channel_id=3, source_target_pairs={{2,0},{3,1}}
  %collective-permute.2 = u8[1,256]{1,0} collective-permute(u8[1,256]{1,0} %fusion.1), channel_id=4, source_target_pairs={{0,1}}
  %cps = u8[1,256]{1,0} collective-permute-start(u8[1,256]{1,0} %x), channel_id=5, source_target_pairs={{4,0}}
  %cpd = u8[1,256]{1,0} collective-permute-done(u8[1,256]{1,0} %cps)
}
"""


def test_parse_permutes_shapes_and_pairs():
    from repro.launch.hlo_analysis import parse_permutes

    instrs = parse_permutes(_HLO)
    assert [i.nbytes for i in instrs] == [512, 256, 256]
    assert instrs[0].pairs == ((2, 0), (3, 1))


def test_cross_pod_permute_bytes_counts_cross_only():
    from repro.launch.hlo_analysis import cross_pod_permute_bytes

    # w=2: devices 0,1 = pod 0; 2,3 = pod 1; 4,5 = pod 2.
    # permute.1 crosses (2->0, 3->1), permute.2 stays inside pod 0,
    # the start/done pair crosses (4->0) and is counted exactly once.
    assert cross_pod_permute_bytes(_HLO, w=2) == 512 + 256


# ---------------------------------- new AST rule: uninstrumented entrypoint


def _lint(src, path):
    from repro.check.ast_rules import lint_source

    return {f.rule for f in lint_source(src, path=path)}


TRAIN_PATH = "src/repro/train/x.py"


def test_lint_uninstrumented_entrypoint_fires():
    from repro.check.ast_rules import L_UNINSTRUMENTED

    src = (
        "import numpy as np\n"
        "def save_all(state):\n"
        "    return np.asarray(state)\n"
    )
    assert L_UNINSTRUMENTED in _lint(src, TRAIN_PATH)


def test_lint_uninstrumented_quiet_outside_scope():
    from repro.check.ast_rules import L_UNINSTRUMENTED

    src = (
        "import numpy as np\n"
        "def save_all(state):\n"
        "    return np.asarray(state)\n"
    )
    assert L_UNINSTRUMENTED not in _lint(src, "src/repro/core/x.py")


def test_lint_uninstrumented_quiet_with_span_or_counter():
    from repro.check.ast_rules import L_UNINSTRUMENTED

    spanny = (
        "import numpy as np\n"
        "from repro import obs\n"
        "def save_all(state):\n"
        "    with obs.span('t.save', cat='train'):\n"
        "        return np.asarray(state)\n"
    )
    county = (
        "import numpy as np\n"
        "from repro import obs\n"
        "def save_all(state):\n"
        "    obs.counter_add('t.saves', 1)\n"
        "    return np.asarray(state)\n"
    )
    assert L_UNINSTRUMENTED not in _lint(spanny, TRAIN_PATH)
    assert L_UNINSTRUMENTED not in _lint(county, TRAIN_PATH)


def test_lint_uninstrumented_exemptions():
    from repro.check.ast_rules import L_UNINSTRUMENTED

    src = (
        "import jax, numpy as np\n"
        "def _private(state):\n"
        "    return np.asarray(state)\n"
        "@jax.jit\n"
        "def jitted(x):\n"
        "    return x\n"
        "def make_step(cfg):\n"
        "    def step(x):\n"
        "        return np.asarray(x)\n"
        "    return step\n"
        "def pure_math(x):\n"
        "    return x + 1\n"
    )
    assert L_UNINSTRUMENTED not in _lint(src, TRAIN_PATH)


def test_lint_uninstrumented_pragma_suppresses_and_is_not_stale():
    from repro.check.ast_rules import L_STALE_PRAGMA, L_UNINSTRUMENTED

    src = (
        "import numpy as np\n"
        "def save_all(state):  # check: ignore[uninstrumented-entrypoint]\n"
        "    return np.asarray(state)\n"
    )
    rules = _lint(src, TRAIN_PATH)
    assert L_UNINSTRUMENTED not in rules
    assert L_STALE_PRAGMA not in rules


def test_lint_tree_on_repo_has_no_uninstrumented_findings():
    from repro.check.ast_rules import L_UNINSTRUMENTED, lint_tree

    records = lint_tree(os.path.join(REPO, "src", "repro"))
    hits = [
        (r.path, f.message)
        for r in records
        for f in r.findings
        if f.rule == L_UNINSTRUMENTED
    ]
    assert hits == []


# ------------------------------------------- mesh captures (subprocess)


def test_traced_self_test_all_caught_exclusively():
    out = run_sub(
        """
        from repro.check.traced import self_test_traced
        rows = self_test_traced()
        assert len(rows) == 9, rows
        for mutation, owner, caught, exclusive in rows:
            assert caught and exclusive, (mutation, owner)
        print("exclusive-ok", len(rows))
        """
    )
    assert "exclusive-ok 9" in out


def test_traced_sweep_is_clean_and_meets_floor():
    out = run_sub(
        """
        import json
        from repro.check.traced import run_traced_sweep
        recs = run_traced_sweep()
        floor = json.load(open("tools/traced_baseline.json"))
        assert len(recs) >= floor["min_traced_records"], len(recs)
        bad = [(r.label, [f.rule for f in r.findings])
               for r in recs if r.status != "PASS"]
        assert not bad, bad
        kinds = {r.kind for r in recs}
        assert kinds == {"repair", "kernel", "hot-path", "checkpoint"}
        print("sweep-ok", len(recs))
        """
    )
    assert "sweep-ok" in out


def test_hlo_cross_bytes_equal_plan_and_eq3_bound():
    out = run_sub(
        """
        from repro.check.traced import capture_spmd_repair
        from repro.launch.hlo_analysis import cross_pod_permute_bytes
        for shape in (("DRC", 6, 4, 3), ("DRC", 9, 6, 3)):
            p = capture_spmd_repair(*shape)
            spec, plan = p.meta["spec"], p.meta["plan"]
            sub = p.meta["sub_bytes"]
            got = cross_pod_permute_bytes(p.hlo, int(p.meta["w"]))
            t = plan.traffic_blocks()["cross_rack_blocks"]
            want = round(t * plan.alpha) * sub
            assert got == want, (shape, got, want)
            code = p.meta["code"]
            bound = round(code.theoretical_cross_rack_blocks()
                          * plan.alpha) * sub
            assert got <= bound, (shape, got, bound)
        print("bytes-ok")
        """
    )
    assert "bytes-ok" in out


def test_spmd_repair_donate_kwarg_runs():
    out = run_sub(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.codes import make_code
        from repro.dist.collectives import spmd_repair
        code = make_code("DRC", 6, 4, 3)
        mesh = jax.make_mesh((3, 2), ("pod", "node"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        rng = np.random.default_rng(0)
        data = rng.integers(0, 256, (code.k * code.alpha, 256),
                            dtype=np.uint8)
        payloads = code.encode(data)
        stacked = jnp.asarray(np.stack(payloads))
        out, spec = spmd_repair(code, 0, stacked, mesh, donate=True)
        got = np.asarray(out)[spec.target_pod * spec.w]
        assert np.array_equal(got, payloads[0])
        print("donate-ok")
        """,
        devices=6,
    )
    assert "donate-ok" in out


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
