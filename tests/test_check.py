"""Tests for `repro.check` — the plan verifier and the AST linter.

Deterministic tests run everywhere; property tests (random valid plans
always PASS, random mutations are caught by the rule that owns them)
additionally want hypothesis and are skipped without it.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.check import PlanError
from repro.check.ast_rules import (
    L_HOST_CAST,
    L_HOST_SYNC,
    L_MUT_DEFAULT,
    L_NP_IN_JIT,
    L_SPAN_WITH,
    L_STALE_PRAGMA,
    L_TRACED_IF,
    lint_source,
    lint_tree,
)
from repro.check.plan import (
    MUTATIONS,
    PLAN_RULES,
    R_COEFFICIENTS,
    R_DECODE_RANK,
    R_DECODE_SHAPE,
    R_RELAYER_INPUT,
    R_SEND_MATRIX,
    R_SRC_SURVIVING,
    R_TARGET_ORDER,
    REGISTRY_SWEEP,
    mutate_plan,
    run_registry_sweep,
    self_test,
    verify_code,
    verify_plan,
    verify_stripwise,
)
from repro.check.report import FAIL, PASS, WARN, CheckReport, Finding
from repro.core.codes import make_code
from repro.core.repair import TARGET, Send

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - env without hypothesis
    HAVE_HYPOTHESIS = False

_CODES: dict = {}


def get_code(family, n, k, r):
    key = (family, n, k, r)
    if key not in _CODES:
        _CODES[key] = make_code(family, n, k, r)
    return _CODES[key]


def fails(findings, rule):
    return [f for f in findings if f.rule == rule and f.severity == FAIL]


# ------------------------------------------------------------ Send validation


def test_send_rejects_non_2d_matrix():
    with pytest.raises(PlanError, match=r"Send 3->-1.*2-D"):
        Send(3, TARGET, np.zeros(4, dtype=np.uint8))


def test_send_rejects_wrong_dtype():
    with pytest.raises(PlanError, match=r"Send 1->2.*uint8"):
        Send(1, 2, np.zeros((2, 2), dtype=np.int32))


def test_send_rejects_empty_input_dim():
    with pytest.raises(PlanError, match=r"Send 0->-1.*no input columns"):
        Send(0, TARGET, np.zeros((2, 0), dtype=np.uint8))


def test_send_error_carries_context():
    try:
        Send(5, 7, np.zeros((1, 0), dtype=np.uint8))
    except PlanError as e:
        assert e.rule == "plan.dag.send-matrix"
        assert e.context["src"] == 5 and e.context["dst"] == 7
    else:
        pytest.fail("expected PlanError")


def test_target_order_mismatch_raises_typed_plan_error():
    code = get_code("DRC", 6, 4, 3)
    plan = code.repair_plan(0)
    bad = dataclasses.replace(
        plan, target_order=[plan.target_order[0] + 1] + plan.target_order[1:]
    )
    with pytest.raises(PlanError) as ei:
        bad._target_unit_coeffs(code.all_node_coeffs())
    assert ei.value.rule == "plan.dag.target-order"
    assert ei.value.context["recorded"][0] == plan.target_order[0] + 1


# ------------------------------------------------------------- plan verifier

VERIFY_SET = [
    ("DRC", 6, 4, 3),  # family 1
    ("DRC", 6, 3, 3),  # family 2
    ("RS", 6, 4, 3),
    ("MSR", 6, 4, 6),
]


@pytest.mark.parametrize("family,n,k,r", VERIFY_SET)
def test_valid_plans_pass_every_rule(family, n, k, r):
    code = get_code(family, n, k, r)
    for rec in verify_code(code):
        assert rec.status in (PASS, WARN), (
            f"{rec.label} failed={rec.failed}: "
            f"{[f.as_dict() for f in rec.findings if f.severity == FAIL]}"
        )


def test_verify_code_records_traffic_info():
    recs = verify_code(get_code("DRC", 6, 4, 3))
    assert len(recs) == 6
    for rec in recs:
        assert rec.info["cross_rack_blocks"] == pytest.approx(2.0)
        assert rec.info["rules_checked"] == len(PLAN_RULES)


def test_stripwise_generator_layer_passes():
    rec = verify_stripwise(get_code("DRC", 9, 6, 3))
    assert rec.status == PASS
    assert rec.failed is None
    assert rec.info["sets"] == 3


@pytest.mark.parametrize("mutation", sorted(MUTATIONS))
def test_mutation_caught_by_owning_rule(mutation):
    code = get_code("DRC", 6, 4, 3)
    plan = code.repair_plan(0)
    owner = MUTATIONS[mutation]
    mutated = mutate_plan(plan, mutation)
    assert fails(verify_plan(code, mutated), owner), (
        f"{mutation} not caught by {owner}"
    )
    # the original (cached) plan must be untouched by the mutation
    assert not [f for f in verify_plan(code, plan) if f.severity == FAIL]


def test_self_test_catches_every_mutation():
    assert all(caught for _, _, caught in self_test())


def test_zeroed_decode_row_owned_by_decode_rank():
    code = get_code("DRC", 6, 3, 3)  # family 2 this time
    plan = code.repair_plan(1)
    d = plan.decode.copy()
    d[1, :] = 0
    bad = dataclasses.replace(plan, decode=d)
    findings = verify_plan(code, bad)
    assert fails(findings, R_DECODE_RANK)
    assert fails(findings, R_COEFFICIENTS)  # and it no longer decodes


def test_decode_shape_rule():
    code = get_code("RS", 6, 4, 3)
    plan = code.repair_plan(0)
    bad = dataclasses.replace(plan, decode=plan.decode[:, :-1])
    assert fails(verify_plan(code, bad), R_DECODE_SHAPE)


def test_src_surviving_rule_catches_failed_node_as_helper():
    code = get_code("RS", 6, 4, 3)
    plan = code.repair_plan(0)
    sends = list(plan.node_sends)
    s = sends[0]
    sends[0] = Send(plan.failed, s.dst, s.matrix.copy())
    bad = dataclasses.replace(plan, node_sends=sends)
    assert fails(verify_plan(code, bad), R_SRC_SURVIVING)


def test_relayer_input_width_rule():
    code = get_code("DRC", 6, 4, 3)
    plan = code.repair_plan(0)
    sends = list(plan.relayer_sends)
    s = sends[0]
    sends[0] = Send(s.src, s.dst, s.matrix[:, :-1].copy())
    bad = dataclasses.replace(plan, relayer_sends=sends)
    assert fails(verify_plan(code, bad), R_RELAYER_INPUT)


def test_non_uint8_matrix_flagged_statically():
    code = get_code("RS", 6, 4, 3)
    plan = code.repair_plan(0)
    sends = list(plan.node_sends)
    s = sends[0]
    bad_send = object.__new__(Send)  # bypass __post_init__, as a
    object.__setattr__(bad_send, "src", s.src)  # deserializer bug would
    object.__setattr__(bad_send, "dst", s.dst)
    object.__setattr__(bad_send, "matrix", s.matrix.astype(np.int32))
    sends[0] = bad_send
    bad = dataclasses.replace(plan, node_sends=sends)
    assert fails(verify_plan(code, bad), R_SEND_MATRIX)


# ------------------------------------------------------------- registry sweep


def test_registry_sweep_covers_every_family_and_four_shapes():
    assert set(REGISTRY_SWEEP) == {"DRC-f1", "DRC-f2", "RS", "MSR-Clay",
                                   "stripwise", "spmd"}
    for family, shapes in REGISTRY_SWEEP.items():
        assert len(shapes) >= 4, family
        if family != "DRC-f2":  # f2's construction fixes r = 3
            assert any(r > 3 for _, _, _, r in shapes), (
                f"{family} sweeps no r>3 placement"
            )


def test_small_sweep_all_pass():
    sweep = {
        "DRC-f1": [("DRC", 6, 4, 3)],
        "DRC-f2": [("DRC", 6, 3, 3)],
        "RS": [("RS", 6, 4, 6)],
        "MSR-Clay": [("MSR", 6, 4, 6)],
        "stripwise": [("DRC", 6, 4, 3)],
    }
    records = run_registry_sweep(sweep)
    # plan records: 6 + 6 + 6 + 6 failed nodes, + 1 stripwise record
    assert len(records) == 25
    assert all(r.status in (PASS, WARN) for r in records)


# ------------------------------------------------------------- report model


def test_report_json_schema(tmp_path):
    sweep = {"RS": [("RS", 6, 4, 6)]}
    report = CheckReport(plan_records=run_registry_sweep(sweep))
    path = report.write_json(str(tmp_path / "report.json"))
    with open(path) as f:
        obj = json.load(f)
    assert obj["version"] == 3  # v2 added lowered_records, v3 traced_records
    assert obj["summary"]["FAIL"] == 0
    assert obj["lowered_records"] == []
    assert obj["traced_records"] == []
    rec = obj["plan_records"][0]
    assert {"label", "family", "n", "k", "r", "failed", "status",
            "findings"} <= set(rec)
    assert rec["status"] == "PASS"


def test_finding_rejects_bad_severity():
    with pytest.raises(ValueError):
        Finding("x", "BOGUS", "msg")


# ----------------------------------------------------------------- AST lint


def rules_of(findings):
    return {f.rule for f in findings}


def test_lint_np_call_in_jit():
    src = (
        "import jax, numpy as np\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return np.sum(x)\n"
    )
    assert L_NP_IN_JIT in rules_of(lint_source(src))


def test_lint_np_in_plain_function_ok():
    src = "import numpy as np\ndef f(x):\n    return np.sum(x)\n"
    assert lint_source(src) == []


def test_lint_traced_if_in_jit_and_static_exemption():
    src = (
        "import functools, jax\n"
        "@functools.partial(jax.jit, static_argnames=('flag',))\n"
        "def f(x, flag):\n"
        "    if flag:\n"
        "        return x\n"
        "    if x > 0:\n"
        "        return -x\n"
        "    return x\n"
    )
    findings = [f for f in lint_source(src) if f.rule == L_TRACED_IF]
    assert len(findings) == 1  # only the `if x > 0` (flag is static)
    assert findings[0].witness["line"] == 6


def test_lint_host_cast_in_jit():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return float(x.sum())\n"
    )
    assert L_HOST_CAST in rules_of(lint_source(src))


def test_lint_pallas_kernel_kwonly_params_are_static():
    src = (
        "import functools\n"
        "from jax.experimental import pallas as pl\n"
        "def _kern(x_ref, o_ref, *, causal: bool):\n"
        "    if causal:\n"
        "        o_ref[...] = x_ref[...]\n"
        "def run(x):\n"
        "    return pl.pallas_call(functools.partial(_kern, causal=True))(x)\n"
    )
    assert lint_source(src) == []


def test_lint_pallas_kernel_positional_if_flagged():
    src = (
        "from jax.experimental import pallas as pl\n"
        "def _kern(x_ref, o_ref):\n"
        "    if x_ref:\n"
        "        o_ref[...] = 0\n"
        "def run(x):\n"
        "    return pl.pallas_call(_kern)(x)\n"
    )
    assert L_TRACED_IF in rules_of(lint_source(src))


def test_lint_block_until_ready_in_library():
    src = "import jax\ndef f(y):\n    jax.block_until_ready(y)\n"
    assert L_HOST_SYNC in rules_of(lint_source(src, "src/repro/serve/x.py"))
    # benchmarks are exempt
    assert lint_source(src, "benchmarks/run.py") == []


def test_lint_pragma_suppression():
    src = (
        "import jax\n"
        "def f(y):\n"
        "    jax.block_until_ready(y)  # check: ignore[host-sync]\n"
    )
    assert lint_source(src, "src/repro/x.py") == []


def test_lint_span_outside_with():
    src = (
        "from repro import obs\n"
        "def f():\n"
        "    s = obs.span('leak')\n"
        "    return 1\n"
    )
    assert L_SPAN_WITH in rules_of(lint_source(src))


def test_lint_span_inside_with_and_forwarding_ok():
    src = (
        "from repro import obs\n"
        "def f():\n"
        "    with obs.span('ok'):\n"
        "        pass\n"
        "def g():\n"
        "    return obs.span('forwarded')\n"
    )
    assert lint_source(src) == []


def test_lint_stale_blanket_pragma_warns():
    src = "x = 1  # check: ignore\n"
    findings = [f for f in lint_source(src) if f.rule == L_STALE_PRAGMA]
    assert len(findings) == 1
    assert findings[0].severity == WARN
    assert findings[0].witness["line"] == 1


def test_lint_stale_listed_rule_warns_with_rule_names():
    src = "x = 1  # check: ignore[host-sync]\n"
    findings = [f for f in lint_source(src) if f.rule == L_STALE_PRAGMA]
    assert len(findings) == 1
    assert findings[0].witness["rules"] == ["host-sync"]


def test_lint_used_pragma_is_not_stale():
    src = (
        "import jax\n"
        "def f(y):\n"
        "    jax.block_until_ready(y)  # check: ignore[host-sync]\n"
    )
    assert lint_source(src, "src/repro/x.py") == []


def test_lint_partially_stale_pragma_flags_only_unused_rules():
    src = (
        "import jax\n"
        "def f(y):\n"
        "    jax.block_until_ready(y)  # check: ignore[host-sync, jit-np]\n"
    )
    findings = [
        f for f in lint_source(src, "src/repro/x.py")
        if f.rule == L_STALE_PRAGMA
    ]
    assert len(findings) == 1
    assert findings[0].witness["rules"] == ["jit-np"]


def test_lint_docstring_pragma_examples_are_inert():
    src = '"""Use `# check: ignore[foo]` to suppress."""\nx = 1\n'
    assert lint_source(src) == []


def test_lint_mutable_default_arg_and_dataclass_field():
    src = (
        "from dataclasses import dataclass, field\n"
        "def f(x=[]):\n"
        "    return x\n"
        "@dataclass\n"
        "class C:\n"
        "    a: list = []\n"
        "    b: list = field(default_factory=list)\n"
    )
    findings = [f for f in lint_source(src) if f.rule == L_MUT_DEFAULT]
    assert len(findings) == 2  # f's default and C.a; C.b is fine


def test_lint_own_tree_is_clean():
    import repro

    root = repro.__path__[0]
    bad = [
        f
        for rec in lint_tree(root)
        for f in rec.findings
        if f.severity == FAIL
    ]
    assert bad == [], [f.message for f in bad]


# ------------------------------------------------------------------ CLI


def test_run_check_cli_ast_only(tmp_path, capsys):
    from tools.run_check import main

    out = tmp_path / "report.json"
    rc = main(["--ast-only", "--json", str(out)])
    assert rc == 0
    obj = json.loads(out.read_text())
    assert obj["summary"]["FAIL"] == 0
    assert capsys.readouterr().out.count("AST lint") == 1


def test_run_check_cli_self_test():
    # the traced-layer self-test shard_maps over a (pod, node) mesh, so
    # the CLI must run in a fresh interpreter where its XLA_FLAGS device
    # override still applies (jax is already initialized in-process here)
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run(
        [sys.executable, "-m", "tools.run_check", "--self-test"],
        capture_output=True, text=True, cwd=repo, timeout=600,
    )
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "self-test OK" in proc.stdout


def test_run_check_cli_strict_warnings_gates_warn_only_run(tmp_path, capsys):
    from tools.run_check import main

    warny = tmp_path / "warny.py"
    warny.write_text("x = 1  # check: ignore\n")  # stale pragma -> WARN
    base = ["--ast-only", "--lint-root", str(tmp_path)]
    assert main(base) == 0  # WARNs alone never gated before
    assert main([*base, "--strict-warnings"]) == 1
    assert "--strict-warnings" in capsys.readouterr().out


def test_run_check_cli_lowered_only_with_baseline(tmp_path, capsys):
    from tools.run_check import main

    report = tmp_path / "lowered.json"
    good = tmp_path / "baseline.json"
    good.write_text('{"min_lowered_records": 1}')
    rc = main(["--lowered-only", "--json", str(report),
               "--baseline", str(good)])
    assert rc == 0
    obj = json.loads(report.read_text())
    assert obj["plan_records"] == []
    families = {r["family"] for r in obj["lowered_records"]}
    assert families == {"spmd-schedule", "shard-rules", "pallas-kernel"}
    assert all(r["status"] == "PASS" for r in obj["lowered_records"])

    # a floor above the sweep width must fail the gate
    harsh = tmp_path / "harsh.json"
    harsh.write_text('{"min_lowered_records": 100000}')
    capsys.readouterr()
    assert main(["--lowered-only", "--baseline", str(harsh)]) == 1
    assert "BASELINE REGRESSION" in capsys.readouterr().out


def test_run_check_committed_baseline_matches_sweep():
    """The committed floor must stay <= the actual sweep width."""
    import pathlib

    from repro.check.lowered import run_lowered_sweep

    baseline = json.loads(
        (pathlib.Path(__file__).parent.parent / "tools"
         / "lowered_baseline.json").read_text()
    )
    assert len(run_lowered_sweep()) >= baseline["min_lowered_records"]


# ------------------------------------------------------- property tests


if HAVE_HYPOTHESIS:

    class TestProperties:
        @settings(max_examples=15, deadline=None)
        @given(
            cfg=st.sampled_from(VERIFY_SET),
            data=st.data(),
        )
        def test_valid_plans_always_pass(self, cfg, data):
            family, n, k, r = cfg
            code = get_code(family, n, k, r)
            failed = data.draw(st.integers(0, code.n - 1))
            plan = code.repair_plan(failed)
            assert not [
                f for f in verify_plan(code, plan) if f.severity == FAIL
            ]

        @settings(max_examples=15, deadline=None)
        @given(
            mutation=st.sampled_from(sorted(MUTATIONS)),
            failed=st.integers(0, 5),
        )
        def test_mutations_always_caught(self, mutation, failed):
            code = get_code("DRC", 6, 4, 3)
            plan = code.repair_plan(failed)
            try:
                mutated = mutate_plan(plan, mutation)
            except ValueError:
                return  # mutation not applicable to this plan shape
            assert fails(verify_plan(code, mutated), MUTATIONS[mutation])

else:  # keep the skip visible in test output rather than silently absent

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_properties_skipped():
        pass


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
