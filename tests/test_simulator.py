"""Simulator validation against the paper's §6 measurements.

One calibration point per operation (noted in costmodel.py); everything
else here is held-out validation.
"""
import pytest

from repro.core.codes import make_code
from repro.storage import ClusterSim

sim = ClusterSim()


def code(fam, n, k, r):
    return make_code(fam, n, k, r)


# ------------------------------------------------------------- Table 3
def test_table3_drc963():
    d = sim.table3_breakdown(code("DRC", 9, 6, 3), block_mib=63.0)
    paper = {
        "disk": 0.354,
        "node_encode": 0.067,
        "inner": 0.039,
        "relayer_encode": 0.191,
        "cross": 1.105,
        "decode": 0.443,
    }
    # transfer stages are exact-model; compute stages within 20%
    assert d["disk"] == pytest.approx(paper["disk"], rel=0.02)
    assert d["inner"] == pytest.approx(paper["inner"], rel=0.05)
    assert d["cross"] == pytest.approx(paper["cross"], rel=0.02)
    assert d["decode"] == pytest.approx(paper["decode"], rel=0.20)
    assert d["relayer_encode"] == pytest.approx(paper["relayer_encode"], rel=0.20)
    assert d["node_encode"] == pytest.approx(paper["node_encode"], rel=0.25)


def test_table3_drc953():
    d = sim.table3_breakdown(code("DRC", 9, 5, 3), block_mib=64.0)
    paper = {
        "disk": 0.361,
        "inner": 0.059,
        "relayer_encode": 0.145,
        "cross": 0.561,
        "decode": 0.32,
    }
    assert d["disk"] == pytest.approx(paper["disk"], rel=0.02)
    assert d["inner"] == pytest.approx(paper["inner"], rel=0.05)
    assert d["cross"] == pytest.approx(paper["cross"], rel=0.02)
    assert d["decode"] == pytest.approx(paper["decode"], rel=0.20)
    assert d["relayer_encode"] == pytest.approx(paper["relayer_encode"], rel=0.20)
    # Family 2 is repair-by-transfer: NodeEncode does no arithmetic
    assert d["node_encode"] == pytest.approx(0.0, abs=1e-6)


def test_cross_rack_is_bottleneck_at_1gbps():
    """§6.2's central claim."""
    for nm, bm in [((9, 6, 3), 63.0), ((9, 5, 3), 64.0)]:
        c = code("DRC", *nm)
        t = sim.stage_times(c, c.repair_plan(0), bm, gateway_gbps=1.0)
        assert t.bottleneck == "cross"


def test_disk_becomes_dominant_at_high_bandwidth():
    """§6.3: at 2 Gb/s the disk read rivals the cross-rack transfer."""
    c = code("DRC", 9, 5, 3)
    t = sim.stage_times(c, c.repair_plan(0), 64.0, gateway_gbps=2.0)
    assert t.disk > t.cross * 0.6


# ------------------------------------------------------ Fig. 6 (recovery)
PAPER_FIG6_GAINS = {0.2: 2.96, 0.5: 2.92, 1.0: 2.81, 2.0: 2.04}


@pytest.mark.parametrize("gbps,gain", sorted(PAPER_FIG6_GAINS.items()))
def test_fig6_drc_vs_rs_recovery_gain(gbps, gain):
    a = sim.node_recovery_throughput(code("DRC", 9, 5, 3), gateway_gbps=gbps)
    b = sim.node_recovery_throughput(code("RS", 9, 5, 3), gateway_gbps=gbps)
    assert a / b == pytest.approx(gain, rel=0.08)


def test_fig6_gain_shrinks_with_bandwidth():
    gains = []
    for g in (0.2, 0.5, 1.0, 2.0):
        a = sim.node_recovery_throughput(code("DRC", 9, 5, 3), gateway_gbps=g)
        b = sim.node_recovery_throughput(code("RS", 9, 5, 3), gateway_gbps=g)
        gains.append(a / b)
    assert all(x >= y - 1e-9 for x, y in zip(gains, gains[1:]))


def test_fig6_drc_beats_msr_when_gateway_bound():
    """DRC(6,3,3) vs MSR(6,3,3) (the paper's MISER) at <= 1 Gb/s."""
    for g in (0.2, 0.5, 1.0):
        a = sim.node_recovery_throughput(code("DRC", 6, 3, 3), gateway_gbps=g)
        b = sim.node_recovery_throughput(code("MSR", 6, 3, 3), gateway_gbps=g)
        assert a > b


# --------------------------------------------------- Fig. 7 (degraded read)
PAPER_FIG7_REDUCTIONS = {0.2: 66.9, 0.5: 62.3, 1.0: 58.0, 2.0: 55.4}


@pytest.mark.parametrize("gbps,red", sorted(PAPER_FIG7_REDUCTIONS.items()))
def test_fig7_drc_vs_rs_degraded_read(gbps, red):
    a = sim.degraded_read_time(code("DRC", 9, 5, 3), gateway_gbps=gbps)
    b = sim.degraded_read_time(code("RS", 9, 5, 3), gateway_gbps=gbps)
    got = 100.0 * (1.0 - a / b)
    assert got == pytest.approx(red, abs=5.0)


def test_degraded_read_decreases_with_bandwidth():
    c = code("DRC", 9, 6, 3)
    ts = [sim.degraded_read_time(c, 63.0, g) for g in (0.2, 0.5, 1.0, 2.0)]
    assert all(x > y for x, y in zip(ts, ts[1:]))


# ------------------------------------------------ Fig. 8 (strip/block size)
def test_fig8a_strip_size_u_shape():
    c = code("DRC", 9, 5, 3)
    strips = [1, 8, 64, 256, 2048, 16384]  # KiB
    tput = [
        sim.node_recovery_throughput(c, strip_kib=s, gateway_gbps=1.0)
        for s in strips
    ]
    best = max(tput)
    # tiny strips lose to call overhead; huge strips lose parallelism
    assert tput[0] < 0.8 * best
    assert tput[-1] < 0.95 * best
    # the paper's optimum is between 8 KiB and 2 MiB
    assert max(tput[1:5]) == best


def test_fig8b_block_size_saturates():
    c = code("DRC", 9, 5, 3)
    blocks = [1, 4, 16, 64, 256]  # MiB
    tput = [
        sim.node_recovery_throughput(c, block_mib=b, gateway_gbps=1.0)
        for b in blocks
    ]
    assert all(x <= y + 1e-9 for x, y in zip(tput, tput[1:3]))
    assert tput[0] < 0.6 * tput[3]
    assert tput[4] == pytest.approx(tput[3], rel=0.10)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
