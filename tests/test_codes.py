"""Property tests for the erasure-code library (paper §3–§4).

Covers: MDS (Goal 1), systematic (Goal 2), exact repair (Goal 3), GF(2^8)
(Goal 4), redundancy (Goal 5), polynomial subpacketization (Goal 6),
relayer traffic bounds (Goal 7), balanced cross-rack traffic (Goal 8),
and the closed-form bandwidths Eq. (1)/(2)/(3).
"""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core.code_base import (
    drc_min_cross_rack_blocks,
    msr_repair_blocks,
    rs_repair_blocks,
)
from repro.core.codes import (
    DRCFamily1,
    DRCFamily2,
    MSRCode,
    RSCode,
    make_code,
    PAPER_CODES,
)

# module-level cache: constructions are deterministic and reusable
_CACHE: dict = {}


def get_code(family, n, k, r=None):
    key = (family, n, k, r)
    if key not in _CACHE:
        _CACHE[key] = make_code(family, n, k, r)
    return _CACHE[key]


PROTO_F1 = [(6, 4, 3), (8, 6, 4), (9, 6, 3)]
PROTO_F2 = [(6, 3, 3), (9, 5, 3)]
MSR_SET = [(6, 4), (6, 3), (8, 6), (8, 4), (9, 6)]


# --------------------------------------------------------------------- MDS
@pytest.mark.parametrize("family,n,k,r", PAPER_CODES)
def test_paper_codes_mds(family, n, k, r):
    code = get_code(family, n, k, r)
    assert code.is_mds()


@pytest.mark.parametrize("family,n,k,r", PAPER_CODES)
def test_paper_codes_systematic(family, n, k, r):
    code = get_code(family, n, k, r)
    ka = code.k * code.alpha
    np.testing.assert_array_equal(
        code.generator[:ka], np.eye(ka, dtype=np.uint8)
    )


# ------------------------------------------------------------ exact repair
@pytest.mark.parametrize("family,n,k,r", PAPER_CODES)
def test_exact_repair_every_node(family, n, k, r):
    code = get_code(family, n, k, r)
    for f in range(code.n):
        assert code.verify_repair(f), f"{code} node {f}"


@pytest.mark.parametrize("n,k,r", PROTO_F1 + PROTO_F2)
def test_repair_payload_roundtrip(n, k, r):
    code = get_code("DRC", n, k, r)
    rng = np.random.default_rng(n * 100 + k)
    data = rng.integers(0, 256, size=(code.k * code.alpha, 48), dtype=np.uint8)
    payloads = dict(enumerate(code.encode(data)))
    for f in range(code.n):
        rec = code.repair(f, {i: p for i, p in payloads.items() if i != f})
        np.testing.assert_array_equal(rec, payloads[f])


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from(PROTO_F1 + PROTO_F2))
def test_decode_from_any_k(seed, cfg):
    n, k, r = cfg
    code = get_code("DRC", n, k, r)
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 256, size=(code.k * code.alpha, 16), dtype=np.uint8)
    payloads = dict(enumerate(code.encode(data)))
    chosen = sorted(rng.choice(code.n, size=code.k, replace=False))
    got = code.decode({i: payloads[i] for i in chosen})
    np.testing.assert_array_equal(got, data)


# --------------------------------------------------------- bandwidth (Eq 1-3)
@pytest.mark.parametrize("family,n,k,r", PAPER_CODES)
def test_cross_rack_bandwidth_matches_closed_form(family, n, k, r):
    code = get_code(family, n, k, r)
    for f in range(code.n):
        t = code.repair_plan(f).traffic_blocks()
        assert t["cross_rack_blocks"] == pytest.approx(
            code.theoretical_cross_rack_blocks()
        ), f"{code} node {f}"


def test_eq1_eq2_eq3_formulas():
    assert rs_repair_blocks(6) == 6
    assert msr_repair_blocks(9, 6) == pytest.approx(8 / 3)
    # the paper's §3.2 examples
    assert drc_min_cross_rack_blocks(6, 3, 3) == pytest.approx(1.0)
    assert drc_min_cross_rack_blocks(9, 6, 3) == pytest.approx(2.0)
    assert drc_min_cross_rack_blocks(9, 5, 3) == pytest.approx(1.0)
    # flat placement reduces Eq.(3) to Eq.(2)
    for n, k in [(6, 3), (6, 4), (8, 4)]:
        assert drc_min_cross_rack_blocks(n, k, n) == pytest.approx(
            msr_repair_blocks(n, k)
        )


def test_theorem1_msr_matches_drc_bound():
    """MSR codes achieve the DRC bound for n-k = 2, r = n/2."""
    for n, k in [(6, 4), (8, 6)]:
        code = get_code("MSR", n, k, n // 2)
        got = code.repair_plan(0).traffic_blocks()["cross_rack_blocks"]
        assert got == pytest.approx(drc_min_cross_rack_blocks(n, k, n // 2))
        assert got == pytest.approx(k / 2)  # the paper's closed form k·B/2


# ------------------------------------------------------------- Goals 5-8
def test_goal5_redundancy_below_2x_family1():
    for n, k, r in PROTO_F1:
        assert get_code("DRC", n, k, r).storage_overhead < 2.0


def test_goal6_polynomial_subpacketization():
    for n, k, r in PROTO_F1:
        assert get_code("DRC", n, k, r).alpha == n - k
    for n, k, r in PROTO_F2:
        assert get_code("DRC", n, k, r).alpha == 2


@pytest.mark.parametrize("n,k,r", PROTO_F1)
def test_goal7_relayer_in_not_more_than_out_family1(n, k, r):
    code = get_code("DRC", n, k, r)
    for f in range(code.n):
        plan = code.repair_plan(f)
        for v in plan.relayers:
            recv, sent = plan.relayer_io_blocks(v)
            assert recv <= sent + 1e-9, f"{code} node {f} relayer {v}"


@pytest.mark.parametrize("n,k,r", PROTO_F2)
def test_goal7_relayer_in_bounded_family2(n, k, r):
    """Family 2 relayers receive (z-1)·B/2 and send B/2; the paper's own
    Table 3 shows DRC(9,5,3) receiving 64 MiB (= B) against 32 MiB sent,
    so the literal Goal-7 inequality does not hold for Family 2 even in
    the paper — we assert the measured paper bound: relayer-in ≤ B."""
    code = get_code("DRC", n, k, r)
    for f in range(code.n):
        plan = code.repair_plan(f)
        for v in plan.relayers:
            recv, sent = plan.relayer_io_blocks(v)
            assert recv <= 1.0 + 1e-9, f"{code} node {f} relayer {v}"
            assert sent == pytest.approx(0.5)


@pytest.mark.parametrize("n,k,r", PROTO_F1 + PROTO_F2)
def test_goal8_balanced_cross_rack(n, k, r):
    code = get_code("DRC", n, k, r)
    for f in range(code.n):
        t = code.repair_plan(f).traffic_blocks()
        per = list(t["per_relayer_cross"].values())
        assert len(set(per)) == 1, f"{code} node {f}: {per}"


# --------------------------------------------------- paper Table-3 traffic
def test_table3_inner_rack_traffic():
    """DRC(9,6,3): relayer receives 2/3 B; DRC(9,5,3): receives 1 B."""
    plan = get_code("DRC", 9, 6, 3).repair_plan(0)
    for v in plan.relayers:
        assert plan.relayer_io_blocks(v)[0] == pytest.approx(2 / 3)
    plan = get_code("DRC", 9, 5, 3).repair_plan(0)
    for v in plan.relayers:
        assert plan.relayer_io_blocks(v)[0] == pytest.approx(1.0)


def test_table3_cross_rack_traffic():
    """DRC(9,6,3) pulls 2 blocks cross-rack; DRC(9,5,3) pulls 1."""
    t = get_code("DRC", 9, 6, 3).repair_plan(0).traffic_blocks()
    assert t["cross_rack_blocks"] == pytest.approx(2.0)
    t = get_code("DRC", 9, 5, 3).repair_plan(0).traffic_blocks()
    assert t["cross_rack_blocks"] == pytest.approx(1.0)


# ----------------------------------------------------------------- MSR zoo
@pytest.mark.parametrize("n,k", MSR_SET)
def test_msr_bandwidth_and_repair(n, k):
    code = get_code("MSR", n, k)
    assert code.is_mds()
    for f in range(code.n):
        assert code.verify_repair(f)
        t = code.repair_plan(f).traffic_blocks()
        assert t["total_blocks"] == pytest.approx(msr_repair_blocks(n, k))


def test_msr_9_6_exists():
    """The paper's footnote 2: systematic MSR(9,6) was unknown in 2017;
    the coupled-layer construction (Ye-Barg'17/Clay'18) provides it."""
    code = get_code("MSR", 9, 6)
    assert code.is_mds()
    assert code.verify_repair(0)


# -------------------------------------------------------- beyond-paper DRC
@pytest.mark.parametrize("n,k", [(12, 9), (12, 7)])
def test_beyond_paper_configs(n, k):
    code = get_code("DRC", n, k)
    assert code.is_mds()
    for f in range(code.n):
        assert code.verify_repair(f)
        t = code.repair_plan(f).traffic_blocks()
        assert t["cross_rack_blocks"] == pytest.approx(
            drc_min_cross_rack_blocks(n, k, code.r)
        )


# ----------------------------------------------------------- rack tolerance
def test_rack_failure_tolerance():
    # hierarchical DRC tolerates exactly one rack failure (paper §3.1 case 2)
    for n, k, r in PROTO_F1 + PROTO_F2:
        code = get_code("DRC", n, k, r)
        assert code.placement.rack_failure_tolerance(n - k) >= 1
    # flat RS(9,6,9) tolerates 3 rack failures
    assert RSCode(9, 6, 9).placement.rack_failure_tolerance(3) == 3


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_rack_erasure_decodable(seed):
    """Losing one whole rack must still leave the stripe decodable."""
    rng = np.random.default_rng(seed)
    n, k, r = 9, 6, 3
    code = get_code("DRC", n, k, r)
    data = rng.integers(0, 256, size=(code.k * code.alpha, 8), dtype=np.uint8)
    payloads = dict(enumerate(code.encode(data)))
    dead_rack = int(rng.integers(0, r))
    alive = {
        i: payloads[i]
        for i in range(n)
        if code.placement.rack_of(i) != dead_rack
    }
    got = code.decode(alive)
    np.testing.assert_array_equal(got, data)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
