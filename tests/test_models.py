"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
reduced same-family config, runs one forward/train step and one decode
step on CPU with finite outputs and correct shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import applicable_shapes, get_config, get_smoke, input_specs, list_archs
from repro.models import backbone
from repro.models.config import SHAPES
from repro.serve import make_decode_step
from repro.train import TrainConfig, init_train_state, make_train_step
from repro.train.data import DataConfig, SyntheticStream

ARCHS = list_archs()


@pytest.fixture(scope="module")
def smoke_states():
    return {}


def _get(smoke_states, arch):
    if arch not in smoke_states:
        cfg = get_smoke(arch)
        tcfg = TrainConfig()
        params, opt, axes = init_train_state(jax.random.key(0), cfg, tcfg)
        smoke_states[arch] = (cfg, tcfg, params, opt)
    return smoke_states[arch]


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch, smoke_states):
    cfg, tcfg, params, opt = _get(smoke_states, arch)
    batch = SyntheticStream(cfg, DataConfig(batch=2, seq=32)).batch_at(0)
    step = jax.jit(make_train_step(cfg, tcfg))
    p2, o2, m = step(params, opt, batch, 5)  # step 5: warmup lr > 0
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually changed
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(arch, smoke_states):
    cfg, tcfg, params, _ = _get(smoke_states, arch)
    batch = SyntheticStream(cfg, DataConfig(batch=2, seq=16)).batch_at(0)
    logits, aux = backbone.forward(params, cfg, batch)
    b = 2
    s = 16 + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, smoke_states):
    cfg, tcfg, params, _ = _get(smoke_states, arch)
    state, _ = backbone.init_decode_state(cfg, batch=2, kv_len=16)
    step = jax.jit(make_decode_step(cfg))
    toks = jnp.array([[1], [2]], jnp.int32)
    logits, state = step(params, state, toks, 0)
    logits2, _ = step(params, state, toks, 1)
    assert logits.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, smoke_states):
    """Sequential decode must agree with the parallel forward pass."""
    import dataclasses

    cfg, tcfg, params, _ = _get(smoke_states, arch)
    if cfg.family == "audio":
        pytest.skip("decode consumes encoder state; covered separately")
    if cfg.moe:
        # drop-free capacity + f32: the token-dropping policy depends on
        # batch size and bf16 flips near-tie routing — control both
        cfg = dataclasses.replace(
            cfg,
            moe=dataclasses.replace(cfg.moe, capacity_factor=8.0),
            param_dtype="float32",
        )
        params, _ = backbone.init_model(jax.random.key(0), cfg)
    b, s = 2, 8
    toks = jax.random.randint(jax.random.key(3), (b, s), 0, cfg.vocab).astype(
        jnp.int32
    )
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["vis_embeds"] = jnp.zeros((b, cfg.vision_tokens, cfg.d_model),
                                        jnp.bfloat16)
    logits_par, _ = backbone.forward(params, cfg, batch)
    state, _ = backbone.init_decode_state(cfg, batch=b, kv_len=s + 4)
    step = jax.jit(make_decode_step(cfg))
    if cfg.family == "vlm":
        pytest.skip("vlm decode starts after the visual prefix")
    outs = []
    for t in range(s):
        lg, state = step(params, state, toks[:, t][:, None], t)
        outs.append(lg)
    got = np.stack([np.asarray(o, np.float32) for o in outs], axis=1)
    want = np.asarray(logits_par, np.float32)
    np.testing.assert_allclose(got, want, rtol=0.15, atol=0.15)


def test_unrolled_matches_scanned():
    """scan_layers=False (the roofline-probe path) is numerically the
    same program as the scanned production path."""
    import dataclasses

    cfg = get_smoke("starcoder2_3b")
    params, _ = backbone.init_model(jax.random.key(0), cfg)
    batch = {"tokens": jnp.arange(32, dtype=jnp.int32).reshape(2, 16) % cfg.vocab}
    l1, _ = backbone.forward(params, cfg, batch)
    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    params2 = [
        jax.tree.map(lambda a: a[i], params["blocks"]) for i in range(cfg.n_layers)
    ]
    p2 = dict(params)
    p2["blocks"] = params2
    l2, _ = backbone.forward(p2, cfg2, batch)
    np.testing.assert_allclose(
        np.asarray(l1, np.float32), np.asarray(l2, np.float32), rtol=2e-2, atol=2e-2
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_sanity(arch):
    """Analytic count_params tracks the real parameter count (smoke cfg)."""
    cfg = get_smoke(arch)
    params, _ = backbone.init_model(jax.random.key(0), cfg)
    real = sum(x.size for x in jax.tree.leaves(params))
    est = cfg.count_params()
    # padded vocab + per-family approximations: generous band
    assert 0.4 * real < est < 2.5 * real, (arch, real, est)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_exact_spec(arch):
    """The full (published) configs carry the exact assigned hyperparams."""
    spec = {
        "command_r_35b": (40, 8192, 64, 8, 22528, 256000),
        "minicpm_2b": (40, 2304, 36, 36, 5760, 122753),
        "starcoder2_7b": (32, 4608, 36, 4, 18432, 49152),
        "starcoder2_3b": (30, 3072, 24, 2, 12288, 49152),
        "xlstm_125m": (12, 768, 4, 4, 0, 50304),
        "internvl2_1b": (24, 896, 14, 2, 4864, 151655),
        "dbrx_132b": (40, 6144, 48, 8, 10752, 100352),
        "grok_1_314b": (64, 6144, 48, 8, 32768, 131072),
        "whisper_small": (12, 768, 12, 12, 3072, 51865),
        "zamba2_1p2b": (38, 2048, 32, 32, 8192, 32000),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab)
    assert got == spec


def test_shape_applicability():
    """long_500k only for sub-quadratic archs (per the assignment)."""
    for arch in ARCHS:
        cfg = get_config(arch)
        shapes = applicable_shapes(cfg)
        if arch in ("xlstm_125m", "zamba2_1p2b"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes


def test_input_specs_all_cells():
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            if SHAPES[shape].kind == "train":
                assert "labels" in specs
            if cfg.family == "audio" and SHAPES[shape].kind != "decode":
                assert "frames" in specs


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
