"""Pallas GF(2^8) kernel vs pure-jnp oracle: shape/dtype sweeps + properties."""
import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import gf as gfnp
from repro.kernels.ops import bit_expand, choose_block_b, gf_matmul, encode_payload
from repro.kernels.gf_matmul import gf_matmul_pallas
from repro.kernels.ref import gf_matmul_ref


def _rand(rng, r, k, b):
    m = rng.integers(0, 256, size=(r, k), dtype=np.uint8)
    x = rng.integers(0, 256, size=(k, b), dtype=np.uint8)
    return m, x


SHAPES = [
    (1, 1, 128),
    (2, 3, 128),
    (3, 6, 256),
    (4, 12, 384),
    (9, 18, 512),
    (8, 27, 1024),
    (16, 64, 2048),
    (27, 162, 512),  # DRC(9,6,3)-sized plan matrix
]


@pytest.mark.parametrize("r,k,b", SHAPES)
def test_kernel_matches_oracle(r, k, b):
    rng = np.random.default_rng(r * 1000 + k * 10 + b)
    m, x = _rand(rng, r, k, b)
    got = np.asarray(gf_matmul(m, jnp.asarray(x), force_kernel=True))
    want = np.asarray(gf_matmul_ref(jnp.asarray(m), jnp.asarray(x)))
    np.testing.assert_array_equal(got, want)
    # and both match the plan-time numpy path
    np.testing.assert_array_equal(want, gfnp.gf_matmul(m, x))


@pytest.mark.parametrize("block_b", [128, 256, 512])
def test_kernel_block_shapes(block_b):
    rng = np.random.default_rng(block_b)
    m, x = _rand(rng, 6, 9, 1024)
    mb = jnp.asarray(bit_expand(m))
    got = np.asarray(
        gf_matmul_pallas(mb, jnp.asarray(x), block_b=block_b, interpret=True)
    )
    np.testing.assert_array_equal(got, gfnp.gf_matmul(m, x))


def test_unaligned_payload_padding():
    rng = np.random.default_rng(5)
    m, x = _rand(rng, 3, 6, 333)  # not a multiple of 128
    got = np.asarray(gf_matmul(m, jnp.asarray(x), force_kernel=True))
    np.testing.assert_array_equal(got, gfnp.gf_matmul(m, x))


def test_small_payload_fallback():
    rng = np.random.default_rng(6)
    m, x = _rand(rng, 3, 6, 17)
    got = np.asarray(gf_matmul(m, jnp.asarray(x)))
    np.testing.assert_array_equal(got, gfnp.gf_matmul(m, x))


@settings(max_examples=20, deadline=None)
@given(
    st.integers(1, 8),
    st.integers(1, 16),
    st.sampled_from([128, 200, 256, 511]),
    st.integers(0, 2**31 - 1),
)
def test_kernel_property_random(r, k, b, seed):
    rng = np.random.default_rng(seed)
    m, x = _rand(rng, r, k, b)
    got = np.asarray(gf_matmul(m, jnp.asarray(x), force_kernel=True))
    np.testing.assert_array_equal(got, gfnp.gf_matmul(m, x))


def test_linearity_over_payload():
    rng = np.random.default_rng(7)
    m, x = _rand(rng, 4, 8, 256)
    y = rng.integers(0, 256, size=x.shape, dtype=np.uint8)
    lhs = np.asarray(gf_matmul(m, jnp.asarray(x ^ y), force_kernel=True))
    rhs = np.asarray(gf_matmul(m, jnp.asarray(x), force_kernel=True)) ^ np.asarray(
        gf_matmul(m, jnp.asarray(y), force_kernel=True)
    )
    np.testing.assert_array_equal(lhs, rhs)


def test_encode_payload_systematic():
    from repro.core.codes import DRCFamily1

    code = DRCFamily1(9, 6)
    rng = np.random.default_rng(8)
    data = rng.integers(0, 256, size=(code.k * code.alpha, 256), dtype=np.uint8)
    coded = np.asarray(encode_payload(code.generator, jnp.asarray(data)))
    np.testing.assert_array_equal(coded[: data.shape[0]], data)
    want = gfnp.gf_matmul(code.generator, data)
    np.testing.assert_array_equal(coded, want)


def test_choose_block_b_bounds():
    for k, r in [(1, 1), (18, 27), (162, 27), (512, 64)]:
        tb = choose_block_b(k, r)
        assert tb % 128 == 0 and 128 <= tb <= 4096


def test_bit_expand_roundtrip_semantics():
    rng = np.random.default_rng(9)
    m = rng.integers(0, 256, size=(5, 7), dtype=np.uint8)
    mb = bit_expand(m)
    assert mb.shape == (40, 56) and mb.dtype == np.int8
    assert set(np.unique(mb)) <= {0, 1}


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
