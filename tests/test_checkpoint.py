"""Erasure-coded checkpointing + fault tolerance tests (paper technique
as a framework feature)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.train import TrainConfig, init_train_state
from repro.train.checkpoint import (
    CheckpointManager,
    encode_state,
    repair_node,
    restore_state,
)
from repro.train.fault_tolerance import (
    FailureDetector,
    FaultToleranceManager,
    StragglerMonitor,
)


def small_state(seed=0):
    key = jax.random.key(seed)
    return {
        "w": jax.random.normal(key, (37, 53), jnp.float32),
        "b": jnp.arange(11, dtype=jnp.int32),
        "nested": {"m": jax.random.normal(key, (5, 7), jnp.bfloat16)},
    }


def trees_equal(a, b):
    fa, _ = jax.tree.flatten(a)
    fb, _ = jax.tree.flatten(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(fa, fb))


@pytest.mark.parametrize("spec", [("DRC", 9, 6, 3), ("DRC", 9, 5, 3), ("RS", 9, 6, 3)])
def test_encode_restore_roundtrip(spec):
    state = small_state()
    ckpt = encode_state(state, family=spec[0], n=spec[1], k=spec[2], r=spec[3])
    got, report = restore_state(ckpt, state)
    assert report.mode == "direct"
    assert trees_equal(got, state)


@pytest.mark.parametrize("failed", range(9))
def test_single_failure_layered_repair(failed):
    state = small_state(1)
    ckpt = encode_state(state, family="DRC", n=9, k=6, r=3)
    avail = set(range(9)) - {failed}
    got, report = restore_state(ckpt, state, available=avail)
    assert trees_equal(got, state)
    if failed < 6:
        assert report.mode == "repair"
        # DRC(9,6,3): Eq.(3) minimum cross-rack traffic
        assert report.cross_rack_blocks == pytest.approx(2.0)


def test_multi_failure_mds_decode():
    state = small_state(2)
    ckpt = encode_state(state, family="DRC", n=9, k=6, r=3)
    got, report = restore_state(ckpt, state, available={0, 2, 4, 5, 7, 8})
    assert report.mode == "decode"
    assert trees_equal(got, state)


def test_unrecoverable_raises():
    state = small_state(3)
    ckpt = encode_state(state, family="DRC", n=9, k=6, r=3)
    with pytest.raises(ValueError, match="unrecoverable"):
        restore_state(ckpt, state, available={0, 1, 2, 3, 4})


def test_repair_node_traffic():
    state = small_state(4)
    ckpt = encode_state(state, family="DRC", n=9, k=5, r=3)
    payload, traffic = repair_node(ckpt, 0)
    assert np.array_equal(payload, ckpt.payloads[0])
    assert traffic["cross_rack_blocks"] == pytest.approx(1.0)  # Eq.(3)


def test_checkpoint_manager_disk(tmp_path):
    state = small_state(5)
    mgr = CheckpointManager(str(tmp_path), family="DRC", n=9, k=6, r=3, keep=2)
    mgr.save(10, state)
    mgr.save(20, state)
    mgr.save(30, state)
    assert mgr.steps() == [20, 30]  # gc keeps last 2
    got, step, report = mgr.load(state)
    assert step == 30 and report.mode == "direct"
    assert trees_equal(got, state)


def test_checkpoint_manager_missing_file(tmp_path):
    import os

    state = small_state(6)
    mgr = CheckpointManager(str(tmp_path), family="DRC", n=9, k=6, r=3)
    mgr.save(1, state)
    os.remove(os.path.join(str(tmp_path), "step_00000001", "node_0.bin"))
    got, _, report = mgr.load(state)
    assert report.mode == "repair" and report.repaired_nodes == [0]
    assert trees_equal(got, state)


def test_checkpoint_manager_corrupt_file(tmp_path):
    import os

    state = small_state(7)
    mgr = CheckpointManager(str(tmp_path), family="DRC", n=9, k=6, r=3)
    mgr.save(1, state)
    path = os.path.join(str(tmp_path), "step_00000001", "node_3.bin")
    with open(path, "r+b") as f:
        f.seek(0)
        f.write(b"\xff\xff\xff\xff")
    got, _, report = mgr.load(state)
    assert report.mode == "repair"  # CRC catches it -> degraded read
    assert trees_equal(got, state)


def test_real_train_state_roundtrip():
    cfg = get_smoke("minicpm_2b")
    params, opt, _ = init_train_state(jax.random.key(0), cfg, TrainConfig())
    state = {"params": params, "opt": opt}
    ckpt = encode_state(state, family="DRC", n=6, k=4, r=3)
    got, report = restore_state(ckpt, state, available={0, 2, 3, 4, 5})
    assert trees_equal(got, state)
    assert report.mode == "repair"


# --------------------------------------------------------- fault tolerance
def test_failure_detector():
    det = FailureDetector(timeout_s=10)
    det.heartbeat(0, now=100.0)
    det.heartbeat(1, now=105.0)
    assert det.failed_nodes(now=112.0) == [0]
    assert det.failed_nodes(now=120.0) == [0, 1]


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=1.5)
    for pod in range(4):
        for _ in range(8):
            mon.report(pod, 1.0 if pod != 2 else 2.0)
    assert mon.stragglers() == [2]
    order = mon.preferred_relayer_order([0, 1, 2, 3])
    assert order[-1] == 2  # straggler deprioritized as relayer


def test_ft_manager_actions():
    mgr = FaultToleranceManager(n=9, k=6, r=3)
    state = small_state(8)
    ckpt = encode_state(state, n=9, k=6, r=3)
    assert mgr.plan_recovery(ckpt, []).kind == "noop"
    assert mgr.plan_recovery(ckpt, [4]).kind == "repair"
    assert mgr.plan_recovery(ckpt, [1, 2]).kind == "decode"
    assert mgr.plan_recovery(ckpt, [1, 2, 3, 4]).kind == "rollback"
    got, report, action = mgr.execute(ckpt, state, [4])
    assert trees_equal(got, state) and action.kind == "repair"
    with pytest.raises(RuntimeError, match="roll back"):
        mgr.execute(ckpt, state, [0, 1, 2, 3])


def test_elastic_rescale():
    mgr = FaultToleranceManager()
    state = small_state(9)
    ckpt = encode_state(state, family="DRC", n=9, k=6, r=3)
    new = mgr.rescale(ckpt, state, n=6, k=4, r=3)
    assert new.code_spec == ("DRC", 6, 4, 3)
    got, report = restore_state(new, state, available={0, 1, 3, 4, 5})
    assert trees_equal(got, state)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
