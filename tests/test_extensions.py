"""Tests for the §7-related-work extensions: multi-failure repair (CORE),
lazy repair, HACFS-style code switching."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core.codes import make_code
from repro.core.multi_failure import (
    CodeSwitcher,
    LazyRepairPolicy,
    multi_failure_repair,
)


def _stripe(code, seed=0, sub=32):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 256, size=(code.k * code.alpha, sub), dtype=np.uint8)
    return data, dict(enumerate(code.encode(data)))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 3))
def test_multi_failure_repair_exact(seed, nfail):
    code = make_code("DRC", 9, 6, 3)
    rng = np.random.default_rng(seed)
    _, payloads = _stripe(code, seed)
    failed = sorted(rng.choice(9, size=nfail, replace=False).tolist())
    avail = {i: p for i, p in payloads.items() if i not in failed}
    out, report = multi_failure_repair(code, failed, avail)
    for f in failed:
        np.testing.assert_array_equal(out[f], payloads[f])
    assert report.cross_rack_blocks + report.inner_rack_blocks == code.k


def test_multi_failure_single_uses_layered_plan():
    code = make_code("DRC", 9, 5, 3)
    _, payloads = _stripe(code)
    avail = {i: p for i, p in payloads.items() if i != 0}
    out, report = multi_failure_repair(code, [0], avail)
    np.testing.assert_array_equal(out[0], payloads[0])
    assert report.cross_rack_blocks == pytest.approx(1.0)  # Eq.(3)


def test_multi_failure_too_many_raises():
    code = make_code("DRC", 9, 6, 3)
    _, payloads = _stripe(code)
    with pytest.raises(ValueError, match="exceed"):
        multi_failure_repair(code, [0, 1, 2, 3], payloads)


def test_lazy_repair_policy():
    pol = LazyRepairPolicy(threshold=2)
    assert pol.on_failure(0) == "defer"
    assert pol.on_degraded_read(0) == "repair_single"
    assert pol.on_degraded_read(5) == "direct"
    assert pol.on_failure(1) == "repair_batch"
    assert pol.on_failure(2) == "repair_now"  # n-k edge
    assert pol.batched_saving_blocks() > 0  # batching beats eager
    pol.repaired([0, 1, 2])
    assert pol.on_failure(7) == "defer"


def test_code_switcher_roundtrip():
    sw = CodeSwitcher()
    rng = np.random.default_rng(0)
    blocks = rng.integers(0, 256, size=(6, 64), dtype=np.uint8)
    # cold by default
    assert sw.target_code(1)[0] == "RS"
    coded = sw.switch(1, blocks)
    cold = make_code(*sw.cold_spec)
    got = cold.decode({i: coded[i] for i in range(cold.k)})
    np.testing.assert_array_equal(got.reshape(6, -1)[:, :64], blocks)
    # heat it up -> hot code
    for _ in range(20):
        sw.record_access(1)
    assert sw.target_code(1)[0] == "DRC"
    assert (1, "hot") in sw.plan_switches()
    coded_hot = sw.switch(1, blocks)
    hot = make_code(*sw.hot_spec)
    got = hot.decode({i: coded_hot[i] for i in range(hot.k)})
    np.testing.assert_array_equal(
        got.reshape(hot.k, -1)[:, :64], blocks.reshape(hot.k, -1)[:, :64]
    )
    # hot stripe repairs cheaper cross-rack than cold
    t_hot = hot.repair_plan(0).traffic_blocks()["cross_rack_blocks"]
    t_cold = cold.repair_plan(0).traffic_blocks()["cross_rack_blocks"]
    assert t_hot < t_cold


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
