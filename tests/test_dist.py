"""Distribution-layer tests: sharding rules, SPMD layered repair,
vocab-parallel xent — multi-device cases run in subprocesses so the
XLA host-device-count flag applies cleanly."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.dist.sharding import Rules, make_rules, resolve_spec

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout=600) -> str:
    env = {
        **os.environ,
        "PYTHONPATH": os.path.join(REPO, "src"),
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
    }
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
        cwd=REPO,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    return proc.stdout


# ----------------------------------------------------------- sharding rules
class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_resolve_spec_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = make_rules("tp")
    # kv=8 heads cannot shard 16 ways -> replicated
    s = resolve_spec(("batch", None, "kv", None), (256, 1, 8, 128), mesh, rules)
    assert s[0] == "data" and s[2] is None
    # vocab 256000 shards fine
    s = resolve_spec(("vocab", "embed"), (256000, 8192), mesh, rules)
    assert s[0] == "model"


def test_resolve_spec_no_double_axis_use():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = make_rules("tp_sp")
    # seq takes model first; heads must not reuse it
    s = resolve_spec(("batch", "seq", "heads", None), (256, 4096, 64, 128), mesh, rules)
    assert s[1] == "model" and s[2] is None


def test_fsdp_rules_shard_embed_over_data():
    mesh = FakeMesh({"data": 16, "model": 16})
    s = resolve_spec(("embed", "ffn"), (8192, 22528), mesh, make_rules("fsdp"))
    assert s == jax.sharding.PartitionSpec("data", "model")


def test_resolve_spec_empty_rules_replicates():
    mesh = FakeMesh({"data": 4, "model": 4})
    s = resolve_spec(("batch", "embed"), (64, 64), mesh, Rules("none", False, {}))
    assert s == jax.sharding.PartitionSpec(None, None)


def test_resolve_spec_unknown_logical_name_replicates():
    mesh = FakeMesh({"data": 4, "model": 4})
    s = resolve_spec(("made_up", "batch"), (64, 64), mesh, make_rules("tp"))
    assert s[0] is None and s[1] == "data"


def test_resolve_spec_skips_size_one_mesh_axis():
    # a trivial (size-1) axis already means replication; keeping the dim
    # unsharded leaves the entry canonical (None, not a no-op axis name)
    mesh = FakeMesh({"data": 1, "model": 4})
    s = resolve_spec(("embed", "ffn"), (64, 64), mesh, make_rules("fsdp"))
    assert s == jax.sharding.PartitionSpec(None, "model")


def test_resolve_spec_arity_mismatch_raises():
    with pytest.raises(ValueError):
        resolve_spec(("batch",), (4, 4), FakeMesh({"data": 2}), make_rules("tp"))


def test_make_rules_rejects_unknown_mode():
    with pytest.raises(ValueError):
        make_rules("3d")


def test_make_rules_multi_pod_prepends_pod_to_batch():
    rules = make_rules("tp", multi_pod=True)
    assert rules.mesh_axes("batch") == ("pod", "data")
    mesh = FakeMesh({"pod": 2, "data": 4, "model": 4})
    s = resolve_spec(("batch", "ffn"), (64, 64), mesh, rules)
    assert s == jax.sharding.PartitionSpec(("pod", "data"), "model")


def test_spmd_spec_traffic_matches_plan_blocks():
    """plan_to_spmd's static schedule must account for exactly the bytes
    the plan DAG claims, layer by layer (the obs counters reuse this)."""
    from repro.core.codes import make_code
    from repro.dist.collectives import expected_cross_units, plan_to_spmd

    sub = 512
    for fam, n, k, r in [("DRC", 9, 6, 3), ("DRC", 9, 5, 3), ("RS", 9, 6, 3)]:
        code = make_code(fam, n, k, r)
        for failed in (0, n - 1):
            plan = code.repair_plan(failed)
            spec = plan_to_spmd(code, plan)
            blocks = plan.traffic_blocks()
            got = spec.traffic_bytes(sub)
            assert got["cross_rack"] == expected_cross_units(plan) * sub
            assert got["cross_rack"] == round(
                blocks["cross_rack_blocks"] * code.alpha) * sub
            assert got["inner_rack"] == round(
                blocks["inner_rack_blocks"] * code.alpha) * sub


# --------------------------------------------------------- SPMD repair (9 dev)
def test_spmd_layered_repair_all_codes():
    out = run_sub(
        """
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.core.codes import make_code
        from repro.dist.collectives import spmd_repair
        mesh = jax.make_mesh((3,3), ('pod','node'),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        rng = np.random.default_rng(0)
        results = []
        for fam, n, k, r in [('DRC',9,6,3), ('DRC',9,5,3), ('RS',9,6,3), ('MSR',9,6,3)]:
            code = make_code(fam, n, k, r)
            data = rng.integers(0,256,size=(code.k*code.alpha, 128), dtype=np.uint8)
            payloads = code.encode(data)
            stacked = jnp.asarray(np.stack(payloads))
            for failed in (0, n-1):
                out, spec = spmd_repair(code, failed, stacked, mesh)
                got = np.asarray(out)[spec.target_pod * spec.w]
                assert np.array_equal(got, payloads[failed]), (fam, failed)
            results.append(f'{fam}({n},{k},{r})')
        print('OK ' + ';'.join(results))
        """,
        devices=9,
    )
    assert "OK" in out


def test_spmd_repair_hlo_cross_pod_bytes_match_plan():
    """The compiled collective schedule must move exactly the plan's
    cross-rack bytes (the paper's Eq. (3) claim, verified in HLO)."""
    out = run_sub(
        """
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.core.codes import make_code
        from repro.dist.collectives import plan_to_spmd, make_spmd_repair
        from repro.launch.hlo_analysis import parse_collectives
        from jax.sharding import PartitionSpec as P
        mesh = jax.make_mesh((3,3), ('pod','node'),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        SUB = 4096
        rows = {}
        for fam, n, k, r in [('DRC',9,6,3), ('RS',9,6,3), ('DRC',9,5,3), ('RS',9,5,3)]:
            code = make_code(fam, n, k, r)
            plan = code.repair_plan(0)
            spec = plan_to_spmd(code, plan)
            fn = jax.shard_map(make_spmd_repair(spec), mesh=mesh,
                               in_specs=P(('pod','node')), out_specs=P(('pod','node')))
            comp = jax.jit(fn).lower(
                jax.ShapeDtypeStruct((code.n, code.alpha, SUB), jnp.uint8)).compile()
            st = parse_collectives(comp.as_text())
            cross = st.bytes_by_op.get('collective-permute', 0) / (code.alpha * SUB)
            rows[f'{fam}{n}{k}{r}'] = [cross, plan.traffic_blocks()['cross_rack_blocks']]
        print(json.dumps(rows))
        """,
        devices=9,
    )
    rows = json.loads(out.strip().splitlines()[-1])
    for label, (hlo, plan) in rows.items():
        assert hlo == pytest.approx(plan, rel=0.01), label
    # and the headline: DRC moves strictly fewer cross-pod bytes than RS
    assert rows["DRC963"][0] < rows["RS963"][0]
    assert rows["DRC953"][0] < rows["RS953"][0]


# ------------------------------------------------- vocab-parallel fused xent
def test_vocab_parallel_xent_matches_plain():
    out = run_sub(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.train.xent import sharded_xent, vocab_parallel_xent
        mesh = jax.make_mesh((2, 4), ('data', 'model'),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        b, s, d, vp, real = 4, 8, 16, 64, 60
        key = jax.random.key(0)
        x = jax.random.normal(key, (b, s, d), jnp.float32)
        w = jax.random.normal(jax.random.key(1), (vp, d), jnp.float32) * 0.3
        labels = jax.random.randint(jax.random.key(2), (b, s), 0, real)
        labels = labels.at[0, 0].set(-1)
        logits = jnp.einsum('bsd,vd->bsv', x, w)
        want = sharded_xent(logits, labels, real)
        with jax.set_mesh(mesh):
            got = jax.jit(lambda x_, w_, l_: vocab_parallel_xent(
                x_, w_, l_, real, mesh=mesh, tile=8))(x, w, labels)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
        # gradients agree too
        g1 = jax.grad(lambda w_: sharded_xent(
            jnp.einsum('bsd,vd->bsv', x, w_), labels, real))(w)
        with jax.set_mesh(mesh):
            g2 = jax.jit(jax.grad(lambda w_: vocab_parallel_xent(
                x, w_, labels, real, mesh=mesh, tile=8)))(w)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)
        print('OK')
        """,
        devices=8,
    )
    assert "OK" in out


def test_moe_spmd_matches_single_device():
    out = run_sub(
        """
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.models import backbone
        from repro.train.data import DataConfig, SyntheticStream
        # f32 + drop-free capacity: bf16 noise flips near-tie top-k routing
        # and local-vs-global capacity drops different tokens; with those
        # controlled the SPMD (a2a EP) layer is bit-for-bit the math of the
        # single-device layer.
        cfg = get_smoke('dbrx_132b')
        cfg = dataclasses.replace(
            cfg,
            moe=dataclasses.replace(cfg.moe, capacity_factor=8.0),
            param_dtype='float32',
        )
        params, _ = backbone.init_model(jax.random.key(0), cfg)
        batch = SyntheticStream(cfg, DataConfig(batch=4, seq=32)).batch_at(0)
        l_single, _ = backbone.forward(params, cfg, batch)
        mesh = jax.make_mesh((2, 4), ('data', 'model'),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        with jax.set_mesh(mesh):
            l_spmd, _ = jax.jit(lambda p, b: backbone.forward(p, cfg, b))(params, batch)
        a = np.asarray(l_single, np.float32); c = np.asarray(l_spmd, np.float32)
        np.testing.assert_allclose(a, c, atol=1e-4)
        print('OK')
        """,
        devices=8,
    )
    assert "OK" in out


def test_spmd_node_recovery_rotates_relayers():
    """Paper §5.2: multi-stripe node recovery in one program, with the
    relayer role rotating per stripe (load balance across helper nodes)."""
    out = run_sub(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.codes import make_code
        from repro.dist.collectives import spmd_node_recovery
        mesh = jax.make_mesh((3,3), ('pod','node'),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        code = make_code('DRC', 9, 6, 3)
        rng = np.random.default_rng(0)
        S = 4
        stripes, payloads = [], []
        for s in range(S):
            data = rng.integers(0,256,size=(code.k*code.alpha, 64), dtype=np.uint8)
            ps = code.encode(data)
            stripes.append(ps)
            payloads.append(np.stack(ps))
        payloads = jnp.asarray(np.stack(payloads))  # (S, n, alpha, sub)
        dead = 0
        out, specs = spmd_node_recovery(code, dead, payloads, mesh)
        out = np.asarray(out)
        for s in range(S):
            got = out[s, specs[s].target_pod * specs[s].w]
            assert np.array_equal(got, stripes[s][dead]), s
        # relayer roles rotate across stripes
        rel_sets = {tuple(sp.rel_idx.tolist()) for sp in specs}
        assert len(rel_sets) > 1, rel_sets
        print('OK')
        """,
        devices=9,
    )
    assert "OK" in out


def test_moe_tp_with_model_sharded_tokens():
    """TP experts + sequence-parallel tokens (the grok train layout):
    partial-F outputs must be combined per token, not across different
    tokens — regression test for the gather/psum/slice pattern."""
    out = run_sub(
        """
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.models import backbone
        from repro.dist.sharding import axis_rules, make_rules
        from repro.train.data import DataConfig, SyntheticStream
        cfg = get_smoke('grok_1_314b')
        cfg = dataclasses.replace(
            cfg,
            moe=dataclasses.replace(cfg.moe, capacity_factor=8.0, sharding='ffn'),
            param_dtype='float32',
        )
        params, _ = backbone.init_model(jax.random.key(0), cfg)
        batch = SyntheticStream(cfg, DataConfig(batch=2, seq=64)).batch_at(0)
        l_single, _ = backbone.forward(params, cfg, batch)
        mesh = jax.make_mesh((2, 4), ('data', 'model'),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        with axis_rules(make_rules('tp_sp')), jax.set_mesh(mesh):
            l_spmd, _ = jax.jit(lambda p, b: backbone.forward(p, cfg, b))(params, batch)
        np.testing.assert_allclose(
            np.asarray(l_single, np.float32), np.asarray(l_spmd, np.float32),
            atol=1e-4)
        print('OK')
        """,
        devices=8,
    )
    assert "OK" in out


# ------------------------------------------------------------- compat shims
def test_compat_native_branch_leaves_modern_jax_untouched(monkeypatch):
    """On a jax that already exposes the symbols (>= 0.5), install() must
    not replace them — upgrading jax silently switches to native impls."""
    from repro.dist import compat

    native_axis_type = object()
    native_set_mesh = object()
    native_shard_map = object()
    native_typeof = object()
    native_pvary = object()

    def native_make_mesh(axis_shapes, axis_names, *, axis_types=None):
        return "native-mesh"

    monkeypatch.setattr(jax.sharding, "AxisType", native_axis_type,
                        raising=False)
    monkeypatch.setattr(jax, "set_mesh", native_set_mesh, raising=False)
    monkeypatch.setattr(jax, "shard_map", native_shard_map, raising=False)
    monkeypatch.setattr(jax, "typeof", native_typeof, raising=False)
    monkeypatch.setattr(jax.lax, "pvary", native_pvary, raising=False)
    monkeypatch.setattr(jax, "make_mesh", native_make_mesh, raising=False)

    compat.install()

    assert jax.sharding.AxisType is native_axis_type
    assert jax.set_mesh is native_set_mesh
    assert jax.shard_map is native_shard_map
    assert jax.typeof is native_typeof
    assert jax.lax.pvary is native_pvary
    assert jax.make_mesh is native_make_mesh  # has axis_types: kept


def test_compat_shim_branch_backfills_04x_jax(monkeypatch):
    """With the modern symbols absent (jax 0.4.x), install() must
    backfill working shims."""
    import jax.numpy as jnp

    from repro.dist import compat

    for mod, name in [
        (jax.sharding, "AxisType"),
        (jax, "set_mesh"),
        (jax.sharding, "get_abstract_mesh"),
        (jax, "shard_map"),
        (jax, "typeof"),
        (jax.lax, "pvary"),
        (jax, "make_mesh"),
    ]:
        monkeypatch.delattr(mod, name, raising=False)

    compat.install()

    # AxisType enum stand-in
    assert jax.sharding.AxisType.Auto is not None

    # set_mesh maintains the ambient stack; get_abstract_mesh reads it
    assert compat.ambient_mesh() is None
    marker = FakeMesh({"i": 1})
    with jax.set_mesh(marker) as m:
        assert m is marker
        assert jax.sharding.get_abstract_mesh() is marker
    assert compat.ambient_mesh() is None

    # typeof returns an aval carrying shape/dtype
    aval = jax.typeof(jnp.ones((2, 3), jnp.float32))
    assert tuple(aval.shape) == (2, 3) and aval.dtype == jnp.float32

    # pvary is the value-level identity without the vma system
    x = jnp.arange(4)
    assert jax.lax.pvary(x, ("i",)) is x

    # make_mesh accepts and drops axis_types
    mesh = jax.make_mesh((1,), ("i",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    assert dict(mesh.shape) == {"i": 1}

    # shard_map shim swallows check_vma and runs on a concrete mesh
    real_mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("i",))
    P = jax.sharding.PartitionSpec
    f = jax.shard_map(lambda a: a * 2, mesh=real_mesh,
                      in_specs=P(), out_specs=P(), check_vma=True)
    np.testing.assert_array_equal(np.asarray(f(jnp.arange(3))),
                                  np.arange(3) * 2)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
