"""Guards on the dry-run / roofline artifacts (skipped if absent, e.g. on
a fresh clone before `python -m repro.launch.orchestrate_dryrun`)."""
import glob
import json
import os

import pytest

DRYRUN = "artifacts/dryrun"
ROOFLINE = "artifacts/roofline"

pytestmark = pytest.mark.skipif(
    not (os.path.isdir(DRYRUN) and glob.glob(os.path.join(DRYRUN, "*.json"))),
    reason="dry-run artifacts not generated",
)


def _cells():
    out = {}
    for p in glob.glob(os.path.join(DRYRUN, "*.json")):
        if p.endswith("summary.json"):
            continue
        r = json.load(open(p))
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def test_all_80_cells_present_and_clean():
    cells = _cells()
    assert len(cells) == 80
    assert all(r["status"] in ("ok", "skipped") for r in cells.values())
    assert {a for a, _, _ in cells} == {
        "command_r_35b", "minicpm_2b", "starcoder2_7b", "starcoder2_3b",
        "xlstm_125m", "internvl2_1b", "dbrx_132b", "grok_1_314b",
        "whisper_small", "zamba2_1p2b",
    }


def test_long_500k_policy():
    cells = _cells()
    for (arch, shape, mesh), r in cells.items():
        if shape != "long_500k":
            continue
        if arch in ("xlstm_125m", "zamba2_1p2b"):
            assert r["status"] == "ok", (arch, mesh)
        else:
            assert r["status"] == "skipped", (arch, mesh)


def test_multi_pod_never_needs_more_memory():
    """Adding the pod axis must shard, not replicate: multi-pod memory per
    device ≤ single-pod (small tolerance for collective scratch)."""
    cells = _cells()
    for (arch, shape, mesh), r in cells.items():
        if mesh != "single" or r["status"] != "ok":
            continue
        other = cells.get((arch, shape, "multi"))
        if not other or other["status"] != "ok":
            continue
        s = r["memory"]["per_device_total_gib"]
        m = other["memory"]["per_device_total_gib"]
        assert m <= s * 1.05 + 0.1, (arch, shape, s, m)


def test_roofline_artifacts_consistent():
    if not glob.glob(os.path.join(ROOFLINE, "*.json")):
        pytest.skip("roofline artifacts not generated")
    for p in glob.glob(os.path.join(ROOFLINE, "*.json")):
        r = json.load(open(p))
        assert r["status"] == "ok", p
        rf = r["roofline"]
        for key in ("compute_s", "memory_s", "collective_s"):
            assert rf[key] >= 0.0
        dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        assert rf[{"compute": "compute_s", "memory": "memory_s",
                   "collective": "collective_s"}[rf["bottleneck"]]] == dom


def test_train_cells_probe_validated():
    """MODEL_FLOPS/HLO ≈ 1 for dense train cells (probe methodology check)."""
    if not glob.glob(os.path.join(ROOFLINE, "*.json")):
        pytest.skip("roofline artifacts not generated")
    dense = {"command_r_35b", "minicpm_2b", "starcoder2_7b", "starcoder2_3b",
             "internvl2_1b"}
    for p in glob.glob(os.path.join(ROOFLINE, "*train_4k*.json")):
        r = json.load(open(p))
        if r["arch"] in dense and r["status"] == "ok":
            assert 0.8 <= r["roofline"]["useful_flops_ratio"] <= 1.3, r["arch"]


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
