"""Regression tests: Fig. 3 observations and Tables 1-2 MTTDL vs the paper."""
import pytest

from repro.core.analysis.bandwidth import (
    cross_rack_table,
    fig3_rows,
    paper_observations,
)
from repro.core.analysis.reliability import (
    MTTDLModel,
    PAPER_TABLE1,
    PAPER_TABLE2,
    table1_rows,
    table2_rows,
)


def test_fig3_measured_equals_closed_form():
    for row in fig3_rows():
        assert row.cross_rack_blocks == pytest.approx(row.closed_form), row.label


def test_fig3_examples_from_paper_text():
    t = cross_rack_table()
    # §3.2 walk-through values (units of blocks)
    assert t["MSR(6,3,6)"] == pytest.approx(5 / 3)
    assert t["MSR(6,3,3)"] == pytest.approx(4 / 3)
    assert t["DRC(6,3,3)"] == pytest.approx(1.0)
    assert t["DRC(9,6,3)"] == pytest.approx(2.0)
    assert t["RS(9,6,3)"] == pytest.approx(4.0)


def test_fig3_percentage_observations():
    obs = paper_observations()
    assert obs["rs86_vs_rs64_pct"] == pytest.approx(50.0)
    assert obs["rs643_saving_pct"] == pytest.approx(25.0)
    assert obs["msr643_saving_pct"] == pytest.approx(20.0)
    assert obs["drc953_vs_rs953_pct"] == pytest.approx(66.7, abs=0.1)
    assert obs["drc953_vs_msr844_pct"] == pytest.approx(33.3, abs=0.1)


def test_fig3_storage_bandwidth_tradeoff():
    """Same n-k: less redundancy -> more cross-rack bandwidth (paper obs 1)."""
    t = cross_rack_table()
    assert t["RS(8,6,8)"] > t["RS(6,4,6)"]
    assert t["DRC(8,6,4)"] > t["DRC(6,4,3)"]


@pytest.mark.parametrize("key", list(PAPER_TABLE1))
def test_table1_matches_paper(key):
    ours = table1_rows()[key]
    for got, want in zip(ours, PAPER_TABLE1[key]):
        assert got == pytest.approx(want, rel=0.02)


@pytest.mark.parametrize("key", list(PAPER_TABLE2))
def test_table2_matches_paper(key):
    ours = table2_rows()[key]
    for got, want in zip(ours, PAPER_TABLE2[key]):
        assert got == pytest.approx(want, rel=0.02)


def test_mttdl_monotonic_in_mttf():
    vals = [
        MTTDLModel(mttf_years=m, r=3, c_single=2.0).mttdl_years()
        for m in (2, 4, 8, 16)
    ]
    assert all(a < b for a, b in zip(vals, vals[1:]))


def test_mttdl_monotonic_in_bandwidth():
    vals = [
        MTTDLModel(gamma_gbps=g, r=3, c_single=2.0).mttdl_years()
        for g in (0.2, 1.0, 5.0)
    ]
    assert all(a < b for a, b in zip(vals, vals[1:]))


def test_hierarchical_beats_flat_without_correlated():
    """Paper §3.4: ~33% MTTDL gain from the minimized cross-rack repair."""
    flat = MTTDLModel(r=9, c_single=8 / 3).mttdl_years()
    hier = MTTDLModel(r=3, c_single=2.0).mttdl_years()
    assert hier / flat == pytest.approx(4 / 3, rel=0.02)


def test_correlated_failures_hurt_hierarchical_more():
    flat_drop = (
        MTTDLModel(r=9, c_single=8 / 3).mttdl_years()
        / MTTDLModel(r=9, c_single=8 / 3, lambda2=0.005).mttdl_years()
    )
    hier_drop = (
        MTTDLModel(r=3, c_single=2.0).mttdl_years()
        / MTTDLModel(r=3, c_single=2.0, lambda2=0.005).mttdl_years()
    )
    assert hier_drop > flat_drop


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
