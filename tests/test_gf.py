"""Property tests for the GF(2^8) arithmetic layer (plan-time + JAX path)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import gf
from repro.core import gf_jax

bytes_st = st.integers(min_value=0, max_value=255)
nz_bytes_st = st.integers(min_value=1, max_value=255)


@given(bytes_st, bytes_st, bytes_st)
def test_field_axioms_mul(a, b, c):
    # commutativity / associativity / identity
    assert gf.gf_mul(a, b) == gf.gf_mul(b, a)
    assert gf.gf_mul(gf.gf_mul(a, b), c) == gf.gf_mul(a, gf.gf_mul(b, c))
    assert gf.gf_mul(a, 1) == a
    assert gf.gf_mul(a, 0) == 0


@given(bytes_st, bytes_st, bytes_st)
def test_distributivity(a, b, c):
    left = gf.gf_mul(a, b ^ c)
    right = gf.gf_mul(a, b) ^ gf.gf_mul(a, c)
    assert left == right


@given(nz_bytes_st)
def test_inverse(a):
    assert gf.gf_mul(a, gf.gf_inv(a)) == 1
    assert gf.gf_div(a, a) == 1


@given(nz_bytes_st, st.integers(min_value=0, max_value=600))
def test_pow_consistency(a, e):
    ref = 1
    for _ in range(e):
        ref = int(gf.gf_mul(ref, a))
    assert gf.gf_pow(a, e) == ref


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matmul_associative_and_linear(m, k, p, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 256, size=(m, k), dtype=np.uint8)
    b = rng.integers(0, 256, size=(k, p), dtype=np.uint8)
    c = rng.integers(0, 256, size=(p, 3), dtype=np.uint8)
    left = gf.gf_matmul(gf.gf_matmul(a, b), c)
    right = gf.gf_matmul(a, gf.gf_matmul(b, c))
    np.testing.assert_array_equal(left, right)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=10), st.integers(min_value=0, max_value=2**31 - 1))
def test_matrix_inverse_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    while True:
        a = rng.integers(0, 256, size=(n, n), dtype=np.uint8)
        if gf.gf_rank(a) == n:
            break
    inv = gf.gf_inv_matrix(a)
    np.testing.assert_array_equal(gf.gf_matmul(a, inv), np.eye(n, dtype=np.uint8))
    np.testing.assert_array_equal(gf.gf_matmul(inv, a), np.eye(n, dtype=np.uint8))


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=2, max_value=10),
    st.integers(min_value=2, max_value=10),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_nullspace(m, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 256, size=(m, n), dtype=np.uint8)
    ns = gf.gf_nullspace(a)
    assert ns.shape[0] == n - gf.gf_rank(a)
    if ns.shape[0]:
        np.testing.assert_array_equal(
            gf.gf_matmul(a, ns.T), np.zeros((m, ns.shape[0]), dtype=np.uint8)
        )


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_solve(m, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 256, size=(m, n), dtype=np.uint8)
    x_true = rng.integers(0, 256, size=(n,), dtype=np.uint8)
    b = gf.gf_matvec(a, x_true)
    x = gf.gf_solve(a, b)
    np.testing.assert_array_equal(gf.gf_matvec(a, x), b)


def test_cauchy_mds():
    g = gf.rs_generator(9, 6)
    # every 6x6 submatrix of a systematic Cauchy generator is invertible
    rng = np.random.default_rng(0)
    for _ in range(50):
        rows = rng.choice(9, size=6, replace=False)
        assert gf.gf_rank(g[rows]) == 6


def test_bitmatrix_mul_equivalence():
    rng = np.random.default_rng(1)
    for _ in range(20):
        c = int(rng.integers(0, 256))
        x = int(rng.integers(0, 256))
        m = gf.gf_mul_bitmatrix(c)
        xbits = np.array([(x >> i) & 1 for i in range(8)], dtype=np.uint8)
        ybits = m @ xbits % 2
        y = int(sum(int(b) << i for i, b in enumerate(ybits)))
        assert y == int(gf.gf_mul(c, x))


def test_bitmatrix_matmul_equivalence():
    rng = np.random.default_rng(2)
    a = rng.integers(0, 256, size=(4, 6), dtype=np.uint8)
    x = rng.integers(0, 256, size=(6, 32), dtype=np.uint8)
    want = gf.gf_matmul(a, x)
    abit = gf.gf_matrix_to_bitmatrix(a)  # (32, 48)
    xbits = np.zeros((48, 32), dtype=np.uint8)
    for j in range(6):
        for i in range(8):
            xbits[8 * j + i] = (x[j] >> i) & 1
    ybits = (abit.astype(np.int32) @ xbits.astype(np.int32)) % 2
    got = np.zeros_like(want)
    for r in range(4):
        for i in range(8):
            got[r] |= (ybits[8 * r + i].astype(np.uint8)) << i
    np.testing.assert_array_equal(got, want)


def test_jax_matmul_matches_numpy():
    rng = np.random.default_rng(3)
    m = rng.integers(0, 256, size=(5, 7), dtype=np.uint8)
    x = rng.integers(0, 256, size=(7, 129), dtype=np.uint8)
    want = gf.gf_matmul(m, x)
    got = np.asarray(gf_jax.gf_matvec_bytes(m, gf_jax.jnp.asarray(x)))
    np.testing.assert_array_equal(got, want)


def test_bits_roundtrip():
    rng = np.random.default_rng(4)
    x = rng.integers(0, 256, size=(3, 17), dtype=np.uint8)
    xj = gf_jax.jnp.asarray(x)
    back = np.asarray(gf_jax.bits_to_bytes(gf_jax.bytes_to_bits(xj)))
    np.testing.assert_array_equal(back, x)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
