"""Training-substrate invariants: schedules, optimizer, fused xent,
restart-safe data, training-loop behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke
from repro.train import (
    AdamWConfig,
    DataConfig,
    ScheduleConfig,
    SyntheticStream,
    TrainConfig,
    init_train_state,
    learning_rate,
    make_train_step,
)
from repro.train.optimizer import adamw_update, global_norm, init_opt_state
from repro.train.xent import sharded_xent, vocab_parallel_xent


# ------------------------------------------------------------------ schedule
@settings(max_examples=20, deadline=None)
@given(st.sampled_from(["cosine", "wsd", "constant"]), st.integers(0, 9999))
def test_lr_bounded_and_nonnegative(kind, step):
    cfg = ScheduleConfig(kind=kind, peak_lr=1e-3, warmup_steps=100, total_steps=10000)
    lr = float(learning_rate(step, cfg))
    assert 0.0 <= lr <= cfg.peak_lr * (1 + 1e-6)  # f32 representation slack


def test_wsd_shape():
    cfg = ScheduleConfig(kind="wsd", peak_lr=1.0, warmup_steps=10,
                         total_steps=100, decay_frac=0.2, min_lr_frac=0.1)
    lrs = [float(learning_rate(s, cfg)) for s in range(100)]
    assert lrs[0] == 0.0 and lrs[10] == pytest.approx(1.0)
    assert all(l == pytest.approx(1.0) for l in lrs[10:80])  # stable phase
    assert lrs[99] < 0.15  # decayed ~10x
    assert all(a >= b - 1e-9 for a, b in zip(lrs[80:], lrs[81:]))  # monotone decay


# ----------------------------------------------------------------- optimizer
def test_adamw_moves_toward_gradient():
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.ones((4, 4))}
    state = init_opt_state(params, AdamWConfig(weight_decay=0.0))
    p2, state, gnorm = adamw_update(params, grads, state, 0.1, AdamWConfig(weight_decay=0.0))
    assert float(gnorm) == pytest.approx(4.0)
    assert np.all(np.asarray(p2["w"]) < 1.0)


def test_grad_clip():
    cfg = AdamWConfig(grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((8,))}
    grads = {"w": jnp.full((8,), 100.0)}
    state = init_opt_state(params, cfg)
    p2, state, gnorm = adamw_update(params, grads, state, 1e-2, cfg)
    # post-clip effective norm is 1 -> bounded first step
    assert np.all(np.abs(np.asarray(p2["w"])) < 0.02)


# -------------------------------------------------------------------- xent
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(30, 70), st.sampled_from([4, 8, 16]))
def test_fused_xent_matches_plain_single_device(seed, real_vocab, tile):
    rng = np.random.default_rng(seed)
    b, s, d, vp = 2, 5, 8, 80
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((vp, d)) * 0.3, jnp.float32)
    labels = jnp.asarray(rng.integers(0, real_vocab, (b, s)), jnp.int32)
    want = sharded_xent(jnp.einsum("bsd,vd->bsv", x, w), labels, real_vocab)
    got = vocab_parallel_xent(x, w, labels, real_vocab, mesh=None, tile=tile)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_xent_ignores_padding_labels():
    x = jnp.ones((1, 3, 4), jnp.float32)
    w = jnp.ones((8, 4), jnp.float32)
    all_pad = jnp.full((1, 3), -1, jnp.int32)
    got = vocab_parallel_xent(x, w, all_pad, 8, mesh=None, tile=4)
    assert float(got) == 0.0


# ---------------------------------------------------------------------- data
def test_data_stream_restart_safe():
    cfg = get_smoke("minicpm_2b")
    s1 = SyntheticStream(cfg, DataConfig(seed=7, batch=2, seq=16))
    s2 = SyntheticStream(cfg, DataConfig(seed=7, batch=2, seq=16))
    for step in (0, 3, 11):
        a, b = s1.batch_at(step), s2.batch_at(step)
        np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert not np.array_equal(
        np.asarray(s1.batch_at(0)["tokens"]), np.asarray(s1.batch_at(1)["tokens"])
    )


def test_labels_are_shifted_tokens():
    cfg = get_smoke("starcoder2_3b")
    b = SyntheticStream(cfg, DataConfig(batch=2, seq=16)).batch_at(0)
    np.testing.assert_array_equal(
        np.asarray(b["labels"][:, :-1]), np.asarray(b["tokens"][:, 1:])
    )
    assert np.all(np.asarray(b["labels"][:, -1]) == -1)


# ------------------------------------------------------------- training loop
def test_loss_decreases_over_steps():
    cfg = get_smoke("starcoder2_3b")
    tcfg = TrainConfig(
        schedule=ScheduleConfig(kind="constant", peak_lr=1e-3, warmup_steps=2)
    )
    params, opt, _ = init_train_state(jax.random.key(0), cfg, tcfg)
    stream = SyntheticStream(cfg, DataConfig(batch=4, seq=64))
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    losses = []
    for i in range(12):
        params, opt, m = step(params, opt, stream.batch_at(i), i)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_microbatched_step_matches_full_batch():
    cfg = get_smoke("minicpm_2b")
    cfg = dataclasses.replace(cfg, param_dtype="float32")
    batch = SyntheticStream(cfg, DataConfig(batch=4, seq=32)).batch_at(0)
    t1 = TrainConfig(microbatches=1)
    t2 = TrainConfig(microbatches=4)
    params, opt, _ = init_train_state(jax.random.key(0), cfg, t1)
    p1, _, m1 = jax.jit(make_train_step(cfg, t1))(params, opt, batch, 5)
    p2, _, m2 = jax.jit(make_train_step(cfg, t2))(params, opt, batch, 5)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
