"""Pallas flash-attention kernel vs the pure-jnp oracle (interpret mode):
shape/dtype sweeps, causal + bidirectional, GQA head-group mapping."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention


def ref_attn(q, k, v, causal=True):
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    groups = h // kvh
    kr = jnp.repeat(k, groups, axis=2)
    vr = jnp.repeat(v, groups, axis=2)
    s = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) / math.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((sq, k.shape[1]), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, vr.astype(jnp.float32)).astype(q.dtype)


SWEEP = [
    # b, sq, sk, h, kvh, d, causal, bq, bk
    (1, 256, 256, 2, 2, 64, True, 128, 128),
    (2, 512, 512, 1, 1, 128, True, 256, 128),
    (1, 256, 512, 2, 2, 64, False, 128, 256),
    (1, 256, 256, 4, 2, 64, True, 128, 128),  # GQA groups=2
    (2, 256, 256, 8, 2, 32, True, 128, 64),  # GQA groups=4
    (1, 128, 384, 3, 1, 64, False, 128, 128),  # MQA, rectangular
]


@pytest.mark.parametrize("b,sq,sk,h,kvh,d,causal,bq,bk", SWEEP)
def test_flash_matches_ref_f32(b, sq, sk, h, kvh, d, causal, bq, bk):
    rng = np.random.default_rng(b * 100 + sq + h)
    q = jnp.asarray(rng.standard_normal((b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, sk, kvh, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, sk, kvh, d)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    want = ref_attn(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


def test_flash_bf16():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.bfloat16)
    got = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    want = ref_attn(q, k, v, True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=3e-2
    )


def test_flash_matches_training_path():
    """The kernel and the pure-JAX chunked attention agree."""
    from repro.models.attention import _chunked_attention

    rng = np.random.default_rng(9)
    b, s, kvh, g, d = 1, 256, 2, 2, 64
    q = jnp.asarray(rng.standard_normal((b, s, kvh, g, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kvh, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kvh, d)), jnp.float32)
    want = _chunked_attention(q / math.sqrt(d) * math.sqrt(d), k, v,
                              causal=True, chunk=128)
    got = flash_attention(
        q.reshape(b, s, kvh * g, d), k, v, causal=True, block_q=128,
        block_k=128, interpret=True,
    ).reshape(b, s, kvh, g, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
