"""Tests for the `repro.obs` tracing/metrics subsystem.

Covers span nesting, counter/gauge aggregation, the Chrome trace_event
export round-trip, zero-op behaviour when disabled, the simulator's
stage-span schema, and the load-bearing cross-check: traced inner-/
cross-rack bytes from an *executed* RepairPlan equal the plan's
symbolic bandwidth accounting for every deployed plan shape.
"""
import json
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.core.codes import make_code
from repro.storage import ClusterSim, StageTimes


# ----------------------------------------------------------------- spans
def test_span_nesting_and_timing():
    with obs.tracing("t") as tr:
        with obs.span("outer", cat="x", tag="a") as outer:
            with obs.span("inner", cat="x"):
                time.sleep(0.005)
            outer.set_attr("post", 1)
    o = tr.spans_named("outer")[0]
    i = tr.spans_named("inner")[0]
    assert i.parent_id == o.span_id and o.parent_id is None
    assert i.dur_us >= 5000
    assert o.dur_us >= i.dur_us
    assert i.start_us >= o.start_us
    assert o.attrs == {"tag": "a", "post": 1}


def test_sibling_spans_share_parent():
    with obs.tracing("t") as tr:
        with obs.span("p") as p:
            with obs.span("a"):
                pass
            with obs.span("b"):
                pass
    a, b = tr.spans_named("a")[0], tr.spans_named("b")[0]
    assert a.parent_id == b.parent_id == p.span_id
    assert b.start_us >= a.start_us + a.dur_us


def test_synthetic_spans_lay_out_on_track_cursor():
    with obs.tracing("t") as tr:
        obs.record_span("s1", 0.5, cat="stage", track="sim:1")
        obs.record_span("s2", 0.25, cat="stage", track="sim:1")
        obs.record_span("other", 1.0, cat="stage", track="sim:2")
    s1, s2 = tr.spans_named("s1")[0], tr.spans_named("s2")[0]
    assert (s1.start_us, s1.dur_us) == (0.0, 500_000.0)
    assert (s2.start_us, s2.dur_us) == (500_000.0, 250_000.0)
    assert tr.spans_named("other")[0].start_us == 0.0  # independent track


def test_threads_get_independent_stacks():
    with obs.tracing("t") as tr:
        def work():
            with obs.span("child"):
                pass
        with obs.span("main_parent"):
            th = threading.Thread(target=work, name="worker")
            th.start()
            th.join()
    child = tr.spans_named("child")[0]
    assert child.track == "worker"
    assert child.parent_id is None  # not nested under another thread's span


# --------------------------------------------------------------- metrics
def test_counter_aggregation_across_labels():
    with obs.tracing("t") as tr:
        obs.counter_add("bytes", 100, scope="inner")
        obs.counter_add("bytes", 50, scope="inner")
        obs.counter_add("bytes", 30, scope="cross")
    assert tr.counter_value("bytes", scope="inner") == 150
    assert tr.counter_value("bytes", scope="cross") == 30
    assert tr.counter_value("bytes") == 180  # unlabelled query sums
    assert tr.counter_value("missing") == 0


def test_counter_rejects_negative():
    with obs.tracing("t") as tr:
        with pytest.raises(ValueError):
            tr.counter_add("c", -1)


def test_gauge_last_write_wins():
    with obs.tracing("t") as tr:
        obs.gauge_set("gbps", 1.0, path="ref")
        obs.gauge_set("gbps", 2.5, path="ref")
    assert tr.metrics.gauge_value("gbps", path="ref") == 2.5
    d = tr.metrics.as_dict()
    assert d["gauges"]["gbps"]["path=ref"] == 2.5


def test_disabled_is_noop():
    assert not obs.enabled()
    assert obs.current() is None
    s = obs.span("nope")
    assert s is obs.NULL_SPAN
    with s:
        s.set_attr("k", "v")  # must not raise
    obs.counter_add("nope", 1)
    obs.gauge_set("nope", 1)
    assert obs.record_span("nope", 1.0) is None


# ---------------------------------------------------------------- export
def test_chrome_trace_roundtrip(tmp_path):
    with obs.tracing("rt") as tr:
        with obs.span("a", cat="c1", n=3):
            obs.counter_add("k", 7, scope="x")
            with obs.span("b"):
                pass
        obs.record_span("sim_stage", 0.125, cat="stage", track="sim:1",
                        code="DRC(9,6,3)")
    path = tmp_path / "trace.json"
    obs.write_chrome_trace(tr, str(path))
    loaded = json.loads(path.read_text())
    spans = obs.spans_from_chrome(loaded)
    orig = sorted(tr.spans, key=lambda s: s.span_id)
    assert [s.name for s in spans] == [s.name for s in orig]
    for got, want in zip(spans, orig):
        assert got.span_id == want.span_id
        assert got.parent_id == want.parent_id
        assert got.cat == want.cat
        assert got.track == want.track
        assert got.start_us == pytest.approx(want.start_us)
        assert got.dur_us == pytest.approx(want.dur_us)
        assert got.attrs == {k: v for k, v in want.attrs.items()}
    counters = [e for e in loaded["traceEvents"] if e.get("ph") == "C"]
    assert counters and counters[0]["name"] == "k"
    assert counters[0]["args"] == {"scope=x": 7.0}


def test_summary_aggregates(tmp_path):
    with obs.tracing("s") as tr:
        for _ in range(3):
            obs.record_span("stage_x", 0.1, cat="stage", track="sim:1")
        obs.counter_add("c", 5)
    summ = obs.summary(tr)
    agg = summ["spans"]["stage_x"]
    assert agg["count"] == 3
    assert agg["total_us"] == pytest.approx(300_000.0)
    assert agg["mean_us"] == pytest.approx(100_000.0)
    assert summ["counters"]["c"][""] == 5
    p = tmp_path / "summary.json"
    obs.write_summary(tr, str(p))
    assert json.loads(p.read_text())["trace"] == "s"


# ----------------------------------------------- repair plan cross-check
PLAN_SHAPES = [
    ("DRC", 9, 6, 3),   # family 1: NodeEncode + RelayerEncode
    ("DRC", 9, 5, 3),   # family 2: repair-by-transfer
    ("RS", 9, 5, 3),    # no layering, direct cross-rack sends
    ("MSR", 6, 3, 3),   # regenerating baseline
]


@pytest.mark.parametrize("fam,n,k,r", PLAN_SHAPES)
def test_traced_bytes_match_symbolic_accounting(fam, n, k, r):
    """Bytes moved by the instrumented executor == traffic_blocks()."""
    code = make_code(fam, n, k, r)
    plan = code.repair_plan(0)
    sub = 128
    rng = np.random.default_rng(1)
    data = rng.integers(0, 256, size=(code.k * code.alpha, sub), dtype=np.uint8)
    nodes = code.encode(data)
    with obs.tracing("xcheck") as tr:
        rebuilt = plan.execute({i: nodes[i] for i in plan.participants()})
    assert np.array_equal(rebuilt, nodes[0])
    symbolic = plan.traffic_blocks()
    block_bytes = code.alpha * sub
    for scope in ("inner", "cross"):
        traced = tr.counter_value(f"repair.bytes.{scope}_rack")
        assert traced == pytest.approx(
            symbolic[f"{scope}_rack_blocks"] * block_bytes
        ), f"{code!r} {scope}-rack bytes diverge from symbolic accounting"
    # per-relayer unit counters reconcile with the plan's relayer sends
    for relayer in plan.relayers:
        _, sent = plan.relayer_io_blocks(relayer)
        traced_units = tr.counter_value("repair.units_cross",
                                        relayer=str(relayer))
        if traced_units:  # only cross-rack relayer sends are counted
            assert traced_units == sent * plan.alpha


def test_repair_span_structure():
    code = make_code("DRC", 9, 6, 3)
    plan = code.repair_plan(0)
    sub = 64
    data = np.zeros((code.k * code.alpha, sub), dtype=np.uint8)
    nodes = code.encode(data)
    with obs.tracing("spans") as tr:
        plan.execute({i: nodes[i] for i in plan.participants()})
    root = tr.spans_named("repair.execute")[0]
    stages = [s for s in tr.spans if s.parent_id == root.span_id]
    assert len(tr.spans_named("repair.node_encode")) == len(plan.node_sends)
    assert len(tr.spans_named("repair.relayer_encode")) == len(plan.relayer_sends)
    assert len(tr.spans_named("repair.decode")) == 1
    assert all(s.cat == "repair" for s in stages)


# ------------------------------------------------------- simulator schema
def test_simulator_stage_spans_match_schema():
    code = make_code("DRC", 9, 5, 3)
    sim = ClusterSim()
    with obs.tracing("sim") as tr:
        t = sim.stage_times(code, code.repair_plan(0), 64.0, 1.0)
    stage_spans = tr.spans_in_cat("stage")
    schema = set(StageTimes(0, 0, 0, 0, 0, 0, 0).as_dict())
    assert {s.name for s in stage_spans} == schema == set(obs.STAGE_NAMES)
    # simulated durations survive the span encoding exactly
    by_name = {s.name: s for s in stage_spans}
    for name, dur in t.as_dict().items():
        assert by_name[name].dur_us == pytest.approx(dur * 1e6)
    # spans tile the track back-to-back in pipeline order
    ordered = sorted(stage_spans, key=lambda s: s.start_us)
    assert [s.name for s in ordered] == list(obs.STAGE_NAMES)


def test_simulator_untouched_without_tracer():
    code = make_code("DRC", 9, 5, 3)
    sim = ClusterSim()
    t = sim.stage_times(code, code.repair_plan(0), 64.0, 1.0)
    assert t.total > 0  # and no tracer state was created
    assert obs.current() is None


# ------------------------------------------------------------- kernels
def test_kernel_span_records_path_and_rate():
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.ops import gf_matmul

    m = np.eye(3, dtype=np.uint8) * 7
    x = jnp.asarray(
        np.random.default_rng(0).integers(0, 256, (3, 64), dtype=np.uint8)
    )
    with obs.tracing("k") as tr:
        gf_matmul(m, x)
    s = tr.spans_named("kernel.gf_matmul")[0]
    assert s.cat == "kernel" and s.attrs["path"] == "ref"
    assert s.attrs["gbps"] > 0
    assert tr.counter_value("kernel.gf_matmul.bytes") == (3 + 3) * 64


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
