"""Lower a ``RepairPlan`` to one SPMD program over a ``(pod, node)`` mesh.

The paper's DoubleR workflow (§2.2) maps onto a device mesh with the
rack structure made explicit: ``pod`` is the rack axis (r racks) and
``node`` the within-rack axis (w = n/r nodes); device (p, j) holds the
(alpha, sub) payload of node ``p*w + j``, matching
``Placement.rack_of``.  The lowering is two-phase:

* :func:`plan_to_spmd` compiles the plan's GF(256) DAG into a *static*
  :class:`SpmdRepairSpec` — stacked per-node NodeEncode matrices,
  per-relayer RelayerEncode matrices re-indexed onto the rack-local
  unit pool, and integer gather schedules for the cross-pod ship and
  the target decode.  Pure numpy; no devices needed, which is what the
  ``spmd.cross_bytes`` verifier rule exploits.
* :func:`make_spmd_repair` turns a spec into a ``shard_map`` body:

  - **inner** — NodeEncode then ``all_gather`` over the ``node`` axis
    *only* (twice when relayers exist: node units, then relayer
    units), so intra-rack aggregation never crosses a pod boundary;
  - **cross** — one ``lax.ppermute`` over ``pod`` per source rack,
    statically sliced to exactly that rack's cross units, so the
    compiled HLO's collective-permute bytes equal
    ``plan.traffic_blocks()["cross_rack_blocks"] * alpha * sub`` — the
    Eq. (3) bound as a property of the *collective schedule*, not just
    the plan;
  - **decode** — the collector (device (target_pod, 0), i.e. output
    row ``target_pod * w``) gathers its canonical unit order and
    applies the decode matrix.

:func:`spmd_repair` runs one stripe; :func:`spmd_node_recovery` runs S
stripes in a single program with the relayer role rotating per stripe
(paper §5.2 load balancing).  Both self-instrument through
``repro.obs`` with the same stage names / byte counters as
``core/repair.py``, so traced SPMD runs cross-check against the plan's
symbolic accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import numpy as np

from repro import obs
from repro.core.code_base import ErasureCode
from repro.core.repair import TARGET, RepairPlan, Send

from . import compat as _compat

_compat.install()


@dataclasses.dataclass(frozen=True)
class SpmdRepairSpec:
    """Static lowering of one RepairPlan onto the (pod, node) mesh."""

    family: str
    n: int
    k: int
    r: int
    alpha: int
    w: int  # nodes per pod (mesh "node" axis size)
    failed: int
    target_pod: int  # rack of the failed node; collector = (target_pod, 0)
    rel_idx: np.ndarray  # (num_relayers,) int32 — relayer node ids
    node_mats: np.ndarray  # (n, nu, alpha) uint8 — stacked NodeEncode rows
    relayer_mats: np.ndarray  # (n, ru, alpha + w*nu) uint8, pool-indexed
    cross_idx: tuple[tuple[int, ...], ...]  # per pod: pool rows it ships
    target_idx: tuple[int, ...]  # decode input rows in pool2, canonical order
    decode: np.ndarray  # (alpha, total units) uint8
    inner_units: int  # units moved intra-rack (traffic_blocks classification)

    @property
    def nu(self) -> int:
        return int(self.node_mats.shape[1])

    @property
    def ru(self) -> int:
        return int(self.relayer_mats.shape[1])

    @property
    def cross_units(self) -> int:
        """Units the collective-permute schedule ships across pods."""
        return sum(len(rows) for rows in self.cross_idx)

    @property
    def pool_rows(self) -> int:
        """Rows in each pod's gathered unit pool before the cross ship."""
        return self.w * self.nu + (self.w * self.ru if self.ru else 0)

    def permute_steps(self) -> tuple[tuple[int, int, tuple[int, ...]], ...]:
        """The declared collective-permute schedule: one ``(src_pod,
        dst_pod, pool_rows_shipped)`` step per pod with scheduled units.

        This is the artifact ``make_spmd_repair`` compiles and the
        lowered-layer verifier (``repro.check.lowered.spmd``) analyzes —
        both read the same steps, so a schedule the verifier proved
        self-send-free and byte-exact is the schedule that runs.
        """
        return tuple(
            (q, self.target_pod, rows)
            for q, rows in enumerate(self.cross_idx)
            if rows
        )

    def traffic_bytes(self, sub_bytes: int) -> dict[str, int]:
        """Scheduled bytes by scope — comparable to plan.traffic_blocks()
        via bytes == blocks * alpha * sub_bytes."""
        return {
            "inner_rack": self.inner_units * sub_bytes,
            "cross_rack": self.cross_units * sub_bytes,
        }


def _node_send_layout(plan: RepairPlan) -> dict[int, list[tuple[Send, int]]]:
    """Per node: its NodeEncode sends in canonical order (dst ascending,
    TARGET=-1 first) with each send's row offset in the stacked matrix."""
    by_src: dict[int, list[Send]] = {}
    for s in plan.node_sends:
        by_src.setdefault(s.src, []).append(s)
    layout: dict[int, list[tuple[Send, int]]] = {}
    for src, sends in by_src.items():
        sends.sort(key=lambda s: s.dst)
        off = 0
        entries: list[tuple[Send, int]] = []
        for s in sends:
            entries.append((s, off))
            off += s.units
        layout[src] = entries
    return layout


def plan_to_spmd(code: ErasureCode, plan: RepairPlan) -> SpmdRepairSpec:
    """Compile a RepairPlan into a static SPMD spec (pure numpy)."""
    pl = plan.placement
    n, r, w = pl.n, pl.r, pl.nodes_per_rack
    alpha = plan.alpha
    target_pod = pl.rack_of(plan.failed)
    layout = _node_send_layout(plan)

    # --- NodeEncode: one zero-padded (nu, alpha) matrix per node
    nu = max(
        (sum(s.units for s, _ in entries) for entries in layout.values()),
        default=0,
    )
    nu = max(nu, 1)
    node_mats = np.zeros((n, nu, alpha), np.uint8)
    send_off: dict[tuple[int, int], int] = {}
    for src, entries in layout.items():
        for s, off in entries:
            node_mats[src, off:off + s.units, :] = s.matrix
            send_off[(s.src, s.dst)] = off

    def y_row(src: int, off: int) -> int:
        # row of node `src`'s unit `off` in the rack-local gathered pool
        return (src % w) * nu + off

    # --- RelayerEncode: columns re-indexed from [own alpha ++ received
    # units in _relayer_input_order] onto [own alpha ++ the full rack
    # pool], so one matrix shape serves every relayer.
    rsends = sorted(plan.relayer_sends, key=lambda s: s.src)
    ru = max((s.units for s in rsends), default=0)
    relayer_mats = np.zeros((n, ru, alpha + w * nu), np.uint8)
    for s in rsends:
        relayer_mats[s.src, :s.units, :alpha] = s.matrix[:, :alpha]
        col = alpha
        for ns in plan._relayer_input_order(s.src):
            off = send_off[(ns.src, ns.dst)]
            for t in range(ns.units):
                relayer_mats[s.src, :s.units, alpha + y_row(ns.src, off + t)] = (
                    s.matrix[:s.units, col]
                )
                col += 1

    def z_row(src: int, row: int) -> int:
        return w * nu + (src % w) * ru + row

    # --- canonical target-unit order (matches build_target_order):
    # node sends to TARGET sorted by src, then relayer sends by src.
    units: list[tuple[int, int]] = []  # (src node, pool row in its pod)
    for s in sorted(
        (x for x in plan.node_sends if x.dst == TARGET), key=lambda x: x.src
    ):
        off = send_off[(s.src, TARGET)]
        for t in range(s.units):
            units.append((s.src, y_row(s.src, off + t)))
    for s in rsends:
        for t in range(s.units):
            units.append((s.src, z_row(s.src, t)))

    # --- cross-pod schedule: pool rows each non-target pod must ship,
    # in canonical-unit order (so received blocks concatenate cleanly)
    pool_rows = w * nu + (w * ru if ru else 0)
    cross_lists: list[list[int]] = [[] for _ in range(r)]
    cross_pos: dict[int, int] = {}  # unit index -> position in its pod list
    for idx, (src, row) in enumerate(units):
        q = pl.rack_of(src)
        if q != target_pod:
            cross_pos[idx] = len(cross_lists[q])
            cross_lists[q].append(row)

    bases: dict[int, int] = {}
    base = pool_rows
    for q in range(r):
        if q == target_pod or not cross_lists[q]:
            continue
        bases[q] = base
        base += len(cross_lists[q])

    target_idx: list[int] = []
    for idx, (src, row) in enumerate(units):
        q = pl.rack_of(src)
        if q == target_pod:
            target_idx.append(row)
        else:
            target_idx.append(bases[q] + cross_pos[idx])

    # --- inner-rack unit count, same classification as traffic_blocks()
    inner = 0
    for s in plan.node_sends:
        dst_rack = target_pod if s.dst == TARGET else pl.rack_of(s.dst)
        if pl.rack_of(s.src) == dst_rack:
            inner += s.units
    for s in rsends:
        if pl.rack_of(s.src) == target_pod:
            inner += s.units

    return SpmdRepairSpec(
        family=code.name,
        n=n, k=code.k, r=r, alpha=alpha, w=w,
        failed=plan.failed,
        target_pod=target_pod,
        rel_idx=np.asarray([s.src for s in rsends], np.int32),
        node_mats=node_mats,
        relayer_mats=relayer_mats,
        cross_idx=tuple(tuple(rows) for rows in cross_lists),
        target_idx=tuple(target_idx),
        decode=np.asarray(plan.decode, np.uint8),
        inner_units=inner,
    )


def make_spmd_repair(spec: SpmdRepairSpec) -> Callable[[Any], Any]:
    """Build the shard_map body: (1, alpha, sub) per device in/out.

    The returned function must run inside ``shard_map`` over a mesh
    with axes ``("pod", "node")`` of sizes (spec.r, spec.w).  Output
    row ``target_pod * w`` (device (target_pod, 0)) carries the
    reconstructed payload; every other row is zero.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.gf_jax import gf_matmul_jnp

    w, nu, ru = spec.w, spec.nu, spec.ru
    node_mats = jnp.asarray(spec.node_mats)
    relayer_mats = jnp.asarray(spec.relayer_mats) if ru else None
    # declared schedule; plan_to_spmd never emits a (q, q) self-send and
    # the lowered verifier rule lowered.spmd.permute-partial proves it
    cross = [
        (q, jnp.asarray(np.asarray(rows, np.int32)))
        for q, dst, rows in spec.permute_steps()
        if q != dst
    ]
    target_idx = jnp.asarray(np.asarray(spec.target_idx, np.int32))
    decode = jnp.asarray(spec.decode)

    def repair(x: Any) -> Any:
        p = jax.lax.axis_index("pod")
        j = jax.lax.axis_index("node")
        dev = p * w + j  # global node id of this device
        own = x[0]  # (alpha, sub)

        # inner: NodeEncode, then aggregate over the node axis only
        a = jax.lax.dynamic_index_in_dim(node_mats, dev, 0, keepdims=False)
        y = gf_matmul_jnp(a, own)  # (nu, sub)
        pool = jax.lax.all_gather(y, "node").reshape(w * nu, -1)
        if relayer_mats is not None:
            # RelayerEncode consumes [own subblocks ++ rack pool]; its
            # units are pooled in-rack too (rows w*nu .. w*nu + w*ru)
            rm = jax.lax.dynamic_index_in_dim(relayer_mats, dev, 0,
                                              keepdims=False)
            z = gf_matmul_jnp(rm, jnp.concatenate([own, pool], axis=0))
            zf = jax.lax.all_gather(z, "node").reshape(w * ru, -1)
            pool = jnp.concatenate([pool, zf], axis=0)

        # cross: each source pod ships exactly its scheduled units to
        # the target pod — one collective-permute per source pod, so
        # compiled cross-pod bytes == sum(len(rows)) * sub
        recvs = [
            jax.lax.ppermute(
                jnp.take(pool, rows, axis=0), "pod",
                [(q, spec.target_pod)],
            )
            for q, rows in cross
        ]
        pool2 = jnp.concatenate([pool, *recvs], axis=0) if recvs else pool

        # decode on the collector; other devices emit zeros
        rec = gf_matmul_jnp(decode, jnp.take(pool2, target_idx, axis=0))
        is_collector = jnp.logical_and(p == spec.target_pod, j == 0)
        return jnp.where(is_collector, rec, jnp.zeros_like(rec))[None]

    return repair


def _check_mesh(spec: SpmdRepairSpec, mesh: Any) -> None:
    shape = dict(mesh.shape)
    want = {"pod": spec.r, "node": spec.w}
    if shape != want:
        raise ValueError(
            f"mesh axes {shape} do not match the code's rack layout {want}"
        )


def _record_schedule(spec: SpmdRepairSpec, sub_bytes: int) -> None:
    """Book the static schedule into the obs counters — same names and
    scope classification as RepairPlan._record_send, so a traced SPMD
    run cross-checks against traffic_blocks() exactly."""
    moved = spec.traffic_bytes(sub_bytes)
    obs.counter_add("repair.bytes.inner_rack", moved["inner_rack"],
                    stage="spmd")
    obs.counter_add("repair.bytes.cross_rack", moved["cross_rack"],
                    stage="spmd")
    for q, rows in enumerate(spec.cross_idx):
        if rows and q != spec.target_pod:
            obs.counter_add("repair.units_cross", len(rows), pod=str(q))


def spmd_repair(
    code: ErasureCode, failed: int, payloads: Any, mesh: Any,
    *, donate: bool = False
) -> tuple[Any, SpmdRepairSpec]:
    """Repair one stripe as a single SPMD program.

    payloads: (n, alpha, sub) uint8, node-major (row i = node i's
    payload; the failed row is ignored).  Returns the (n, alpha, sub)
    output — row ``spec.target_pod * spec.w`` is the reconstruction —
    plus the static spec.  With ``donate=True`` the payload buffer is
    donated to XLA (in-place repair; the caller's array is invalidated).
    """
    import jax
    from jax.sharding import PartitionSpec as P

    plan = code.repair_plan(failed)
    spec = plan_to_spmd(code, plan)
    _check_mesh(spec, mesh)
    sub_bytes = int(payloads.shape[-1])
    fn = jax.shard_map(
        make_spmd_repair(spec), mesh=mesh,
        in_specs=P(("pod", "node")), out_specs=P(("pod", "node")),
    )
    jit_fn = jax.jit(fn, donate_argnums=0 if donate else ())
    # the three stages execute fused inside one XLA program, so the
    # stage spans carry the static schedule (unit counts) and the
    # counters carry the bytes; wall time lives on the decode span,
    # which encloses the actual dispatch
    with obs.span("repair.spmd", cat="repair", failed=failed,
                  family=spec.family, alpha=spec.alpha, sub_bytes=sub_bytes):
        with obs.span("repair.inner", cat="repair", units=spec.inner_units):
            _record_schedule(spec, sub_bytes)
        with obs.span("repair.cross", cat="repair", units=spec.cross_units,
                      permutes=len([r for r in spec.cross_idx if r])):
            pass
        with obs.span("repair.decode", cat="repair",
                      units=len(spec.target_idx)):
            out = jit_fn(payloads)
    return out, spec


def spmd_node_recovery(
    code: ErasureCode, failed: int, payloads: Any, mesh: Any
) -> tuple[Any, list[SpmdRepairSpec]]:
    """Recover a whole node — S stripes — in one SPMD program.

    payloads: (S, n, alpha, sub) uint8.  Stripe s uses
    ``repair_plan(failed, rotation=s)`` so the relayer role rotates
    across the helper nodes of each remote rack (paper §5.2: node-level
    repair load balance).  Returns ((S, n, alpha, sub), specs).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    n_stripes = int(payloads.shape[0])
    specs: list[SpmdRepairSpec] = []
    bodies: list[Callable[[Any], Any]] = []
    for s in range(n_stripes):
        spec = plan_to_spmd(code, code.repair_plan(failed, rotation=s))
        _check_mesh(spec, mesh)
        specs.append(spec)
        bodies.append(make_spmd_repair(spec))
    sub_bytes = int(payloads.shape[-1])

    def body(x: Any) -> Any:  # (S, 1, alpha, sub) per device
        return jnp.stack([fn(x[s]) for s, fn in enumerate(bodies)], axis=0)

    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=P(None, ("pod", "node")),
        out_specs=P(None, ("pod", "node")),
    )
    relayer_loads: dict[str, int] = {}
    for spec in specs:
        for rel in spec.rel_idx.tolist():
            relayer_loads[str(rel)] = relayer_loads.get(str(rel), 0) + 1
    with obs.span("repair.spmd_node_recovery", cat="repair", failed=failed,
                  family=specs[0].family if specs else "", stripes=n_stripes,
                  distinct_relayer_sets=len(
                      {tuple(sp.rel_idx.tolist()) for sp in specs}
                  )):
        for spec in specs:
            _record_schedule(spec, sub_bytes)
        out = jax.jit(fn)(payloads)
    return out, specs


def cross_units_scheduled(spec: SpmdRepairSpec) -> int:
    """Cross-pod units the compiled schedule will move (for verifiers)."""
    return spec.cross_units


def expected_cross_units(plan: RepairPlan) -> int:
    """Cross-rack units by the plan's own accounting (blocks * alpha)."""
    blocks = float(plan.traffic_blocks()["cross_rack_blocks"])
    return round(blocks * plan.alpha)
