"""jax API shims so one distribution layer runs on jax 0.4.x and 0.5+.

The sharded code paths (``repro.dist``, ``repro.train.xent``,
``repro.models.mlp``, the launch modules) are written against the
current public API — ``jax.shard_map``, ``jax.set_mesh``,
``jax.sharding.AxisType``, ``jax.lax.pvary``, ``jax.typeof`` — which
jax 0.4.x does not expose yet.  Rather than version-forking every call
site, :func:`install` backfills each missing symbol with a
semantically equivalent shim built on the APIs 0.4.x *does* have
(``jax.experimental.shard_map``, concrete ``Mesh`` contexts, the
replication-check-off path where the vma type system does not exist).

Every shim is ``hasattr``-guarded: on a jax that already provides the
symbol, ``install`` is a no-op, so upgrading jax silently switches the
repo onto the native implementations (ROADMAP "revisit when jax is
upgraded" item).  ``install`` is idempotent and runs at ``import
repro`` time so subprocess entry points get the shims no matter which
submodule they import first.
"""
from __future__ import annotations

import contextlib
import enum
import inspect
import math
from typing import Any, Callable, Iterator

import jax

_AMBIENT: list[Any] = []  # mesh stack maintained by the set_mesh shim


class _AxisType(enum.Enum):
    """Stand-in for ``jax.sharding.AxisType`` (jax >= 0.5)."""

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _shim_axis_type() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        setattr(jax.sharding, "AxisType", _AxisType)


def _shim_make_mesh() -> None:
    native: Any = getattr(jax, "make_mesh", None)
    if native is not None and "axis_types" in inspect.signature(native).parameters:
        return
    if native is None:
        def _base(axis_shapes: Any, axis_names: Any) -> Any:
            import numpy as np

            count = math.prod(axis_shapes)
            devs = np.asarray(jax.devices()[:count]).reshape(axis_shapes)
            return jax.sharding.Mesh(devs, axis_names)
        base: Callable[..., Any] = _base
    else:
        base = native

    def make_mesh(axis_shapes: Any, axis_names: Any, *,
                  axis_types: Any = None, **kwargs: Any) -> Any:
        # 0.4.x has no axis-type annotations; Auto is its only behaviour,
        # so the argument is accepted and dropped.
        del axis_types
        return base(axis_shapes, axis_names, **kwargs)

    setattr(jax, "make_mesh", make_mesh)


def _shim_set_mesh() -> None:
    if hasattr(jax, "set_mesh"):
        if not hasattr(jax.sharding, "get_abstract_mesh"):
            # partial backport (unlikely): expose the getter side too
            setattr(jax.sharding, "get_abstract_mesh", ambient_mesh)
        return

    @contextlib.contextmanager
    def set_mesh(mesh: Any) -> Iterator[Any]:
        _AMBIENT.append(mesh)
        try:
            yield mesh
        finally:
            _AMBIENT.pop()

    setattr(jax, "set_mesh", set_mesh)
    if not hasattr(jax.sharding, "get_abstract_mesh"):
        setattr(jax.sharding, "get_abstract_mesh", ambient_mesh)


def ambient_mesh() -> Any:
    """The mesh installed by the ``set_mesh`` shim (None when unset).

    On 0.4.x this returns the *concrete* Mesh — exactly what
    ``jax.experimental.shard_map`` and ``NamedSharding`` want — while
    callers written against ``get_abstract_mesh()`` keep working
    because a concrete Mesh satisfies the same ``.shape`` /
    ``.axis_names`` / ``.empty`` protocol.
    """
    return _AMBIENT[-1] if _AMBIENT else None


def _shim_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f: Callable[..., Any], *, mesh: Any = None,
                  in_specs: Any, out_specs: Any, **kwargs: Any) -> Any:
        # check_vma / check_rep: 0.4.x predates the vma type system, and
        # its static replication checker rejects valid programs that the
        # vma rules accept (psum-of-partial patterns), so it stays off.
        kwargs.pop("check_vma", None)
        if mesh is None:
            mesh = ambient_mesh()
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)

    setattr(jax, "shard_map", shard_map)


def _shim_typeof() -> None:
    if not hasattr(jax, "typeof"):
        setattr(jax, "typeof", lambda x: jax.core.get_aval(x))


def _shim_pvary() -> None:
    if not hasattr(jax.lax, "pvary"):
        # pvary only adjusts the vma *type*; with the vma system absent
        # the value-level semantics are the identity.
        setattr(jax.lax, "pvary", lambda x, axis_names: x)


def install() -> None:
    """Install every missing shim (idempotent; no-op on current jax)."""
    _shim_axis_type()
    _shim_make_mesh()
    _shim_set_mesh()
    _shim_shard_map()
    _shim_typeof()
    _shim_pvary()
