"""``repro.dist`` — the distribution layer.

Two halves (docs/architecture.md §5):

* :mod:`repro.dist.sharding` — logical-axis rule tables mapping model
  dimension names (batch/seq/embed/ffn/…) to mesh axes, resolved to
  ``PartitionSpec``s with divisibility fallback; the ``axis_rules`` /
  ``current_rules`` context pair; ``logical_constraint`` backing
  ``repro.models.common.constrain``.
* :mod:`repro.dist.collectives` — lowers a ``RepairPlan`` to one SPMD
  program over a ``(pod, node)`` mesh: inner-rack aggregation on the
  ``node`` axis only, relayer→collector transfer as collective-permutes
  across ``pod`` whose compiled bytes equal the plan's cross-rack
  accounting (the Eq. (3) claim, checked in HLO).

Importing this package (or any ``repro.*`` module — see
``repro/__init__.py``) installs the :mod:`repro.dist.compat` shims so
the same sources run on jax 0.4.x and current jax.
"""
from . import compat as _compat

_compat.install()
