"""Logical-axis sharding rules → ``PartitionSpec`` resolution.

Model code names array dimensions with *logical* axes
(``repro.models.common.LOGICAL``: batch/seq/embed/ffn/heads/kv/vocab/
expert) and never mentions mesh axes.  A rule table — one per
parallelism mode — maps each logical name to an ordered tuple of mesh
axes it *may* shard over; :func:`resolve_spec` turns (logical names,
concrete shape, mesh) into a ``PartitionSpec`` with two guarantees:

* **divisibility fallback** — a dimension that does not divide evenly
  by a candidate mesh axis is replicated instead (never an XLA error);
* **no double use** — a mesh axis consumed by an earlier dimension of
  the same spec is skipped for later ones (first come, first served).

Modes: ``tp`` (tensor parallel), ``tp_sp`` (+ sequence parallel),
``fsdp`` (embed sharded over data), ``fsdp_sp``, ``tp2d`` (ffn/vocab
over model×data).  ``multi_pod=True`` prepends the ``pod`` axis to the
batch rule (data parallelism across pods — the paper's rack analogue).

``axis_rules(rules)`` installs a rule table for a ``with`` scope;
``current_rules()`` reads it (defaulting to ``tp``);
``logical_constraint`` applies the resolved spec as a real
``with_sharding_constraint`` whenever an ambient mesh exists, making
``repro.models.common.constrain`` more than a annotation.
"""
from __future__ import annotations

import contextlib
import warnings
from typing import Any, Iterator, Mapping, Sequence

import jax

from . import compat as _compat

_compat.install()

Names = Sequence[str | None]

# Mode -> logical axis -> ordered mesh-axis candidates.  Axes listed
# earlier win; a multi-axis entry (tp2d ffn/vocab) shards one dimension
# over the product of every candidate that fits.
_BASE_TABLES: dict[str, dict[str, tuple[str, ...]]] = {
    "tp": {
        "batch": ("data",),
        "seq": (),
        "embed": (),
        "ffn": ("model",),
        "heads": ("model",),
        "kv": ("model",),
        "vocab": ("model",),
        "expert": ("model",),
    },
}
_BASE_TABLES["tp_sp"] = {**_BASE_TABLES["tp"], "seq": ("model",)}
_BASE_TABLES["fsdp"] = {**_BASE_TABLES["tp"], "embed": ("data",)}
_BASE_TABLES["fsdp_sp"] = {**_BASE_TABLES["fsdp"], "seq": ("model",)}
_BASE_TABLES["tp2d"] = {
    **_BASE_TABLES["tp"],
    "ffn": ("model", "data"),
    "vocab": ("model", "data"),
}

MODES = tuple(sorted(_BASE_TABLES))


class Rules:
    """Immutable logical-axis → mesh-axes rule table."""

    def __init__(self, mode: str, multi_pod: bool,
                 table: Mapping[str, tuple[str, ...]]) -> None:
        self.mode = mode
        self.multi_pod = multi_pod
        self._table = dict(table)

    def mesh_axes(self, name: str) -> tuple[str, ...]:
        """Mesh-axis candidates for one logical axis (empty = replicate)."""
        return self._table.get(name, ())

    def __repr__(self) -> str:
        pod = ", multi_pod" if self.multi_pod else ""
        return f"Rules({self.mode!r}{pod})"


def make_rules(mode: str = "tp", *, multi_pod: bool = False) -> Rules:
    """Build the rule table for one parallelism mode."""
    try:
        table = dict(_BASE_TABLES[mode])
    except KeyError:
        raise ValueError(
            f"unknown sharding rules mode {mode!r}; available: {MODES}"
        ) from None
    if multi_pod:
        table["batch"] = ("pod", *table["batch"])
    return Rules(mode, multi_pod, table)


# ------------------------------------------------------------- resolution
def resolve_spec(
    names: Names,
    shape: Sequence[int],
    mesh: Any,
    rules: Rules | None = None,
) -> jax.sharding.PartitionSpec:
    """Map logical axis names + a concrete shape to a PartitionSpec.

    ``mesh`` needs only a ``.shape`` mapping of axis name → size (a
    real Mesh, an AbstractMesh, or a test double).  Every dimension is
    sharded over the longest prefix-product of its candidate axes that
    (a) exist in the mesh, (b) are unused so far in this spec, and
    (c) keep the dimension evenly divisible; otherwise it falls back
    to replication.
    """
    if len(names) != len(shape):
        raise ValueError(
            f"logical names {tuple(names)} do not match shape {tuple(shape)}"
        )
    rules = current_rules() if rules is None else rules
    mesh_shape: Mapping[str, int] = dict(mesh.shape)
    used: set[str] = set()
    entries: list[str | tuple[str, ...] | None] = []
    for name, dim in zip(names, shape):
        if name is None:
            entries.append(None)
            continue
        chosen: list[str] = []
        divisor = 1
        for axis in rules.mesh_axes(name):
            size = mesh_shape.get(axis)
            if size is None or size <= 1 or axis in used:
                continue
            if dim % (divisor * size) != 0:
                continue
            chosen.append(axis)
            divisor *= size
        used.update(chosen)
        if not chosen:
            entries.append(None)
        elif len(chosen) == 1:
            entries.append(chosen[0])
        else:
            entries.append(tuple(chosen))
    return jax.sharding.PartitionSpec(*entries)


def resolve_specs(
    spec_tree: Any,
    shape_tree: Any,
    mesh: Any,
    rules: Rules | None = None,
) -> Any:
    """Resolve a pytree of logical-axis tuples against matching shapes.

    ``spec_tree`` leaves are tuples of logical names (AxisSpec);
    ``shape_tree`` is a congruent tree of arrays / ShapeDtypeStructs.
    """
    rules = current_rules() if rules is None else rules

    def is_axes(x: Any) -> bool:
        return isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x
        )

    return jax.tree.map(
        lambda axes, arr: resolve_spec(axes, arr.shape, mesh, rules),
        spec_tree,
        shape_tree,
        is_leaf=is_axes,
    )


# ----------------------------------------------------------- rule context
_RULES_STACK: list[Rules] = []
_DEFAULT_RULES = make_rules("tp")


@contextlib.contextmanager
def axis_rules(rules: Rules) -> Iterator[Rules]:
    """Install ``rules`` as the ambient table for the ``with`` scope."""
    _RULES_STACK.append(rules)
    try:
        yield rules
    finally:
        _RULES_STACK.pop()


def current_rules() -> Rules:
    """The innermost ``axis_rules`` table, or the ``tp`` default."""
    return _RULES_STACK[-1] if _RULES_STACK else _DEFAULT_RULES


# ------------------------------------------------------------ constraints
def _ambient_mesh() -> Any:
    """Duplicate of models.common.ambient_mesh, kept here to avoid a
    dist ↔ models import cycle (constrain imports this module)."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is None:
        return None
    mesh = get()
    if mesh is None or getattr(mesh, "empty", False):
        return None
    return mesh


_WARNED_NO_MESH = [False]


def _warn_rules_without_mesh() -> None:
    if _WARNED_NO_MESH[0]:
        return
    _WARNED_NO_MESH[0] = True
    warnings.warn(
        "axis_rules(...) is active but no ambient mesh is set "
        "(jax.set_mesh / use_mesh): logical_constraint degrades to a "
        "no-op, so activations will not be sharded as the rules request",
        RuntimeWarning,
        stacklevel=4,
    )


def logical_constraint(x: jax.Array, names: Names) -> jax.Array:
    """Constrain ``x`` to the spec its logical axes resolve to.

    No-op (with a one-time warning if rules were explicitly set) when
    there is no ambient mesh, and outside of tracing — eager arrays are
    already placed.
    """
    mesh = _ambient_mesh()
    if mesh is None:
        if _RULES_STACK:
            _warn_rules_without_mesh()
        return x
    if not isinstance(x, jax.core.Tracer):
        return x
    spec = resolve_spec(names, x.shape, mesh)
    if all(entry is None for entry in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec)
    )
