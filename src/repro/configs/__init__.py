"""Architecture registry: --arch <id> resolves here.

Each module defines `CONFIG` (the exact published configuration) and
`smoke()` (a reduced same-family config for CPU tests).  `input_specs`
builds the ShapeDtypeStruct stand-ins for every (arch × shape) dry-run
cell without allocating anything.
"""
from __future__ import annotations

import importlib

from repro.models.config import SHAPES, ArchConfig, ShapeConfig

ARCHS = [
    "command_r_35b",
    "minicpm_2b",
    "starcoder2_7b",
    "starcoder2_3b",
    "xlstm_125m",
    "internvl2_1b",
    "dbrx_132b",
    "grok_1_314b",
    "whisper_small",
    "zamba2_1p2b",
]

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}
_ALIAS.update({
    "command-r-35b": "command_r_35b",
    "minicpm-2b": "minicpm_2b",
    "starcoder2-7b": "starcoder2_7b",
    "starcoder2-3b": "starcoder2_3b",
    "xlstm-125m": "xlstm_125m",
    "internvl2-1b": "internvl2_1b",
    "dbrx-132b": "dbrx_132b",
    "grok-1-314b": "grok_1_314b",
    "whisper-small": "whisper_small",
    "zamba2-1.2b": "zamba2_1p2b",
})


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_ALIAS.get(name, name)}")
    return mod.CONFIG


def get_smoke(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_ALIAS.get(name, name)}")
    return mod.smoke()


def list_archs() -> list[str]:
    return list(ARCHS)


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """Which of the four shape cells an architecture runs.

    long_500k needs a sub-quadratic decode path (SSM/hybrid); pure
    full-attention archs skip it (recorded as skips in EXPERIMENTS.md).
    """
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        shapes.append("long_500k")
    return shapes


def input_specs(cfg: ArchConfig, shape: ShapeConfig | str, *, for_train: bool = None):
    """ShapeDtypeStruct stand-ins for one dry-run cell (no allocation)."""
    import jax
    import jax.numpy as jnp

    if isinstance(shape, str):
        shape = SHAPES[shape]
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    batch: dict = {}
    if shape.kind in ("train", "prefill"):
        s_text = s
        if cfg.family == "vlm" and cfg.vision_tokens:
            s_text = s - cfg.vision_tokens
            batch["vis_embeds"] = sds((b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = sds((b, s_text), jnp.int32)
        if shape.kind == "train":
            batch["labels"] = sds((b, s), jnp.int32)
        if cfg.family == "audio":
            batch["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    else:  # decode: one new token against a kv_len = seq cache
        batch["tokens"] = sds((b, 1), jnp.int32)
    return batch
