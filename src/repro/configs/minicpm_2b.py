"""minicpm-2b [dense]: 40L d=2304 36H (MHA) d_ff=5760 vocab=122753.

WSD schedule; mup-style depth/embed scaling (llama-like arch).
[arXiv:2404.06395; hf]
"""
import dataclasses
import math

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab=122753,
    mlp_act="swiglu",
    tie_embeddings=True,
    residual_scale=1.4 / math.sqrt(40),  # scale_depth / sqrt(L)
    embed_scale=12.0,
    rope_theta=10000.0,
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="minicpm-smoke", n_layers=2, d_model=144, n_heads=4,
        n_kv_heads=4, d_ff=384, vocab=512,
        residual_scale=1.4 / math.sqrt(2), remat="none",
    )
