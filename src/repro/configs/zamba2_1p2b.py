"""zamba2-1.2b [hybrid]: 38 Mamba2 blocks d=2048, ssm_state=64, plus one
weight-shared attention+MLP block (32H, d_ff=8192) invoked every 6 blocks.

O(1)-per-token SSM decode -> runs the long_500k shape.
[arXiv:2411.15242; hf]
"""
import dataclasses

from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    mlp_act="gelu",
    # chunk=64: the SSD intra-chunk decay tensor is O(B·ck²·H) f32 — at
    # ck=256 that is 16 GiB/device on train_4k; 64 is the standard choice
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=64),
    shared_attn_every=6,
    tie_embeddings=True,
    supports_long_context=True,
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="zamba2-smoke", n_layers=5, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=256, vocab=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=32),
        shared_attn_every=2, remat="none",
    )
