"""xlstm-125m [ssm]: 12L d=768 4H vocab=50304 — sLSTM + mLSTM blocks.

d_ff=0 per the assignment (no separate MLP; the m/sLSTM blocks carry the
capacity).  O(1)-per-token decode -> runs the long_500k shape.
[arXiv:2405.04517; unverified]
"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    slstm_every=4,  # every 4th block is sLSTM (9 mLSTM : 3 sLSTM)
    tie_embeddings=True,
    supports_long_context=True,
    scan_layers=False,
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="xlstm-smoke", n_layers=4, d_model=64, n_heads=2,
        n_kv_heads=2, vocab=512, remat="none",
    )
