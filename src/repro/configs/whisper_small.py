"""whisper-small [audio]: enc-dec 12L+12L d=768 12H d_ff=3072 vocab=51865.

Conv frontend is a STUB: input_specs supplies precomputed frame
embeddings (1500 frames) to the encoder. [arXiv:2212.04356; unverified]
"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    mlp_act="gelu",
    norm="layernorm",
    encoder_decoder=True,
    encoder_layers=12,
    encoder_seq=1500,
    frontend="audio",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="whisper-smoke", n_layers=2, encoder_layers=2,
        d_model=96, n_heads=6, n_kv_heads=6, d_ff=256, vocab=512,
        encoder_seq=32, remat="none",
    )
