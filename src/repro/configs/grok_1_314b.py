"""grok-1-314b [moe]: 64L d=6144 48H (kv=8) d_ff=32768/expert vocab=131072.

8 experts, top-2.  8 experts don't divide the 16-way model axis, so the
expert FFN dim carries the model sharding instead (MoEConfig.sharding
is advisory; resolve_spec drops non-dividing axes automatically).
Optimizer state bf16 (see DESIGN.md §6).  [hf:xai-org/grok-1; unverified]
"""
import dataclasses

from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    mlp_act="gelu",
    rope_theta=1e4,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32768, sharding="ffn"),
    opt_state_dtype="bfloat16",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="grok-smoke", n_layers=2, d_model=128, n_heads=8,
        n_kv_heads=2, d_ff=256, vocab=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=256),
        opt_state_dtype="float32", remat="none",
    )
