"""starcoder2-7b [dense]: 32L d=4608 36H (kv=4) d_ff=18432 vocab=49152.

GQA + RoPE, GeLU MLP with biases, layernorm. [arXiv:2402.19173; hf]
"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab=49152,
    mlp_act="gelu",
    qkv_bias=True,
    norm="layernorm",
    rope_theta=1e5,
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="starcoder2-7b-smoke", n_layers=2, d_model=128,
        n_heads=8, n_kv_heads=2, d_ff=512, vocab=512, remat="none",
    )
