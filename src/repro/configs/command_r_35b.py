"""command-r-35b [dense]: 40L d=8192 64H (kv=8) d_ff=22528 vocab=256000.

Cohere-style: parallel attention/FFN block, no biases, tied embeddings,
logit scaling. [hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    parallel_block=True,
    mlp_act="swiglu",
    norm="layernorm",
    tie_embeddings=True,
    logit_scale=0.0625,
    rope_theta=8e6,
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="command-r-smoke", n_layers=2, d_model=128, n_heads=8,
        n_kv_heads=2, d_ff=352, vocab=512, remat="none",
    )
