"""dbrx-132b [moe]: 40L d=6144 48H (kv=8) d_ff=10752/expert vocab=100352.

16 experts, top-4 (fine-grained).  Expert dim shards over the model axis
(EP); optimizer state kept in bf16 so the 256-chip v5e pod fits.
[hf:databricks/dbrx-base; unverified]
"""
import dataclasses

from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    mlp_act="swiglu",
    norm="layernorm",
    rope_theta=5e5,
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752),
    opt_state_dtype="bfloat16",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="dbrx-smoke", n_layers=2, d_model=128, n_heads=8,
        n_kv_heads=2, d_ff=256, vocab=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=256),
        opt_state_dtype="float32", remat="none",
    )
