"""internvl2-1b [vlm]: 24L d=896 14H (kv=2) d_ff=4864 vocab=151655.

InternViT frontend is a STUB: input_specs supplies 256 precomputed patch
embeddings prepended to the token stream (Qwen2-0.5B-like LM backbone).
[arXiv:2404.16821; hf]
"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    mlp_act="swiglu",
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
    vision_tokens=256,
    frontend="vision",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="internvl2-smoke", n_layers=2, d_model=112, n_heads=7,
        n_kv_heads=1, d_ff=320, vocab=512, vision_tokens=16, remat="none",
    )
