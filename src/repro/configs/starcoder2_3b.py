"""starcoder2-3b [dense]: 30L d=3072 24H (kv=2) d_ff=12288 vocab=49152.

GQA + RoPE. [arXiv:2402.19173; hf]
"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab=49152,
    mlp_act="gelu",
    qkv_bias=True,
    norm="layernorm",
    rope_theta=1e5,
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="starcoder2-3b-smoke", n_layers=2, d_model=96,
        n_heads=6, n_kv_heads=2, d_ff=384, vocab=512, remat="none",
    )
