import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile one (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware:
``jax.jit(step).lower(*ShapeDtypeStructs).compile()`` must succeed on
the 16×16 single-pod mesh AND the 2×16×16 multi-pod mesh for every
assigned (architecture × input shape); ``memory_analysis()`` proves it
fits, ``cost_analysis()`` + the parsed collective schedule feed the
roofline table (EXPERIMENTS.md §Dry-run / §Roofline).

Nothing is allocated: parameters, optimizer state, KV caches and batches
are all ShapeDtypeStruct stand-ins (abstract init via jax.eval_shape).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-3b \
      --shape train_4k --mesh single --rules tp_sp --out artifacts/dryrun
"""
import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import applicable_shapes, get_config, input_specs
from repro.dist.sharding import axis_rules, make_rules, resolve_specs
from repro.launch.hlo_analysis import roofline_from
from repro.launch.mesh import make_production_mesh
from repro.models import backbone
from repro.models.common import AxisSpec
from repro.models.common import spec as axspec
from repro.models.config import SHAPES, ArchConfig
from repro.serve.serve_step import make_decode_step, make_prefill_step
from repro.train import TrainConfig, make_train_step
from repro.train.optimizer import init_opt_state, opt_state_axes


def model_flops_global(cfg: ArchConfig, shape, kind: str) -> float:
    n_active = cfg.active_params()
    if kind == "train":
        return 6.0 * n_active * shape.tokens
    if kind == "prefill":
        return 2.0 * n_active * shape.tokens
    return 2.0 * n_active * shape.global_batch  # decode: one new token


def abstract_train_state(cfg: ArchConfig, tcfg: TrainConfig):
    cap = {}

    def initp(key):
        p, axes = backbone.init_model(key, cfg)
        cap["axes"] = axes
        return p, init_opt_state(p, tcfg.optimizer)

    pshapes, oshapes = jax.eval_shape(initp, jax.random.key(0))
    return pshapes, oshapes, cap["axes"]


def abstract_decode_state(cfg: ArchConfig, batch: int, kv_len: int):
    cap = {}

    def inits():
        st, axes = backbone.init_decode_state(cfg, batch, kv_len)
        cap["axes"] = axes
        return st

    sshapes = jax.eval_shape(inits)
    return sshapes, cap["axes"]


def _specs(mesh, spec_tree, shape_tree):
    return resolve_specs(spec_tree, shape_tree, mesh)


def _named(mesh, spec_tree, shape_tree):
    specs = _specs(mesh, spec_tree, shape_tree)
    return jax.tree.map(
        lambda s: jax.NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )


def _batch_axes(batch):
    out = {}
    for k in batch:
        if k in ("tokens", "labels"):
            out[k] = axspec("batch", None)
        else:  # vis_embeds / frames
            out[k] = axspec("batch", None, "embed")
    return out


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    rules_mode: str = "tp_sp",
    microbatches: int = 1,
    attn_chunk: int = 512,
    remat: str | None = None,
    opt_dtype: str | None = None,
    accum_dtype: str = "float32",
    verbose: bool = True,
) -> dict:
    cfg = get_config(arch)
    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    shape = SHAPES[shape_name]
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "rules": rules_mode,
        "microbatches": microbatches,
        "attn_chunk": attn_chunk,
        "remat": cfg.remat,
        "params_b": round(cfg.params_billions, 3),
        "active_params_b": round(cfg.active_params() / 1e9, 3),
    }
    if shape_name not in applicable_shapes(cfg):
        result.update(
            status="skipped",
            reason="pure full-attention arch: long_500k needs sub-quadratic decode",
        )
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(rules_mode, multi_pod=multi_pod)
    result["n_devices"] = mesh.size
    t0 = time.time()

    from repro.train.optimizer import AdamWConfig

    tcfg = TrainConfig(
        optimizer=AdamWConfig(state_dtype=opt_dtype or cfg.opt_state_dtype),
        microbatches=microbatches,
        attn_chunk=attn_chunk,
        accum_dtype=accum_dtype,
    )
    batch_shapes = input_specs(cfg, shape)
    kind = shape.kind

    with axis_rules(rules), jax.set_mesh(mesh):
        if kind == "train":
            pshapes, oshapes, paxes = abstract_train_state(cfg, tcfg)
            oaxes = opt_state_axes(paxes)
            p_sh = _named(mesh, paxes, pshapes)
            o_sh = _named(mesh, oaxes, oshapes)
            b_sh = _named(mesh, _batch_axes(batch_shapes), batch_shapes)
            step_fn = make_train_step(
                cfg, tcfg, param_specs=_specs(mesh, paxes, pshapes)
            )
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_sh, o_sh, b_sh, None),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(
                pshapes, oshapes, batch_shapes, jax.ShapeDtypeStruct((), jnp.int32)
            )
        elif kind == "prefill":
            pshapes, _, paxes = abstract_train_state(cfg, tcfg)
            p_sh = _named(mesh, paxes, pshapes)
            b_sh = _named(mesh, _batch_axes(batch_shapes), batch_shapes)
            fn = make_prefill_step(cfg, chunk=attn_chunk)
            jitted = jax.jit(fn, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(pshapes, batch_shapes)
        else:  # decode
            pshapes, _, paxes = abstract_train_state(cfg, tcfg)
            p_sh = _named(mesh, paxes, pshapes)
            sshapes, saxes = abstract_decode_state(
                cfg, shape.global_batch, shape.seq_len
            )
            s_sh = _named(mesh, saxes, sshapes)
            tok_sh = _named(
                mesh,
                {"tokens": axspec("batch", None)},
                {"tokens": batch_shapes["tokens"]},
            )["tokens"]
            fn = make_decode_step(cfg)
            jitted = jax.jit(
                fn,
                in_shardings=(p_sh, s_sh, tok_sh, None),
                out_shardings=(None, s_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(
                pshapes,
                sshapes,
                batch_shapes["tokens"],
                jax.ShapeDtypeStruct((), jnp.int32),
            )
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    roof, colls = roofline_from(
        compiled, model_flops_global(cfg, shape, kind), mesh.size
    )
    result.update(
        status="ok",
        kind=kind,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory={
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "per_device_total_gib": round(
                (
                    ma.argument_size_in_bytes
                    + ma.temp_size_in_bytes
                    + ma.output_size_in_bytes
                    - ma.alias_size_in_bytes
                )
                / 2**30,
                3,
            ),
        },
        roofline=roof.as_dict(),
        collectives={
            "bytes_by_op": colls.bytes_by_op,
            "count_by_op": colls.count_by_op,
            "largest": colls.largest,
        },
    )
    if verbose:
        mem = result["memory"]["per_device_total_gib"]
        print(
            f"[dryrun] {arch} x {shape_name} x {result['mesh']} ({rules_mode}): "
            f"OK mem/dev={mem} GiB compile={t_compile:.0f}s "
            f"bottleneck={roof.bottleneck} "
            f"terms(c/m/x)=({roof.compute_s:.4f},{roof.memory_s:.4f},{roof.collective_s:.4f})s"
        )
        print(compiled.memory_analysis())
        ca = compiled.cost_analysis()
        print({k: ca[k] for k in ("flops", "bytes accessed", "transcendentals") if k in ca})
        print("collectives:", result["collectives"]["bytes_by_op"])
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--rules", default="tp_sp",
                    choices=["tp", "fsdp", "tp_sp", "fsdp_sp", "tp2d"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--attn-chunk", type=int, default=512)
    ap.add_argument("--remat", default=None, choices=[None, "none", "dots", "full"])
    ap.add_argument("--opt-dtype", default=None)
    ap.add_argument("--accum-dtype", default="float32")
    ap.add_argument("--out", default=None, help="directory for the JSON artifact")
    args = ap.parse_args()
    res = run_cell(
        args.arch,
        args.shape,
        multi_pod=args.mesh == "multi",
        rules_mode=args.rules,
        microbatches=args.microbatches,
        attn_chunk=args.attn_chunk,
        remat=args.remat,
        opt_dtype=args.opt_dtype,
        accum_dtype=args.accum_dtype,
    )
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        tag = f"{res['arch']}__{res['shape']}__{res['mesh']}__{res['rules']}"
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(res, f, indent=1)
    return 0 if res.get("status") in ("ok", "skipped") else 1


if __name__ == "__main__":
    raise SystemExit(main())
