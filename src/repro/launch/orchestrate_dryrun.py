"""Run every (arch × shape × mesh) dry-run cell as a fresh subprocess.

Each cell gets its own process so the 512-device XLA flag is applied
cleanly and a pathological cell cannot poison the rest.  Results land as
JSON artifacts consumed by benchmarks/roofline.py and EXPERIMENTS.md.

  PYTHONPATH=src python -m repro.launch.orchestrate_dryrun \
      --out artifacts/dryrun [--mesh single multi] [--arch ...]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import ARCHS
from repro.models.config import SHAPES

# per-cell overrides: sharding rules / microbatching / accumulation dtype
# chosen to fit 16 GiB HBM per v5e chip (derivations in EXPERIMENTS.md
# §Dry-run: fsdp for 35B+ weights, sequence-parallel residuals for
# train_4k, bf16 grad accumulation for the 100B+ MoEs)
BIG = ("dbrx_132b", "grok_1_314b", "command_r_35b")
OVERRIDES: dict[tuple[str, str], list[str]] = {}
for _a in BIG:
    OVERRIDES[(_a, "train_4k")] = [
        "--rules", "fsdp_sp", "--microbatches", "8", "--accum-dtype", "bfloat16",
    ]
    OVERRIDES[(_a, "prefill_32k")] = ["--rules", "fsdp_sp"]
    OVERRIDES[(_a, "decode_32k")] = ["--rules", "fsdp"]
# §Perf-1: FSDP weight gathers recur per microbatch; command-r fits at mb=2
OVERRIDES[("command_r_35b", "train_4k")] = [
    "--rules", "fsdp_sp", "--microbatches", "2", "--accum-dtype", "bfloat16",
]
# §Perf-2: MoE decode serves from resident 2-D-sharded expert weights
OVERRIDES[("dbrx_132b", "decode_32k")] = ["--rules", "tp2d"]
OVERRIDES[("grok_1_314b", "decode_32k")] = ["--rules", "tp2d"]
OVERRIDES[("zamba2_1p2b", "train_4k")] = ["--rules", "tp_sp", "--microbatches", "2"]


def cell_rules(arch: str, shape: str) -> str:
    ov = OVERRIDES.get((arch, shape))
    if ov:
        return ov[ov.index("--rules") + 1]
    return "tp_sp" if shape == "train_4k" else "tp"


def cell_cmd(arch: str, shape: str, mesh: str, out: str) -> list[str]:
    cmd = [
        sys.executable,
        "-m",
        "repro.launch.dryrun",
        "--arch",
        arch,
        "--shape",
        shape,
        "--mesh",
        mesh,
        "--out",
        out,
    ]
    cmd += OVERRIDES.get(
        (arch, shape), ["--rules", "tp_sp" if shape == "train_4k" else "tp"]
    )
    return cmd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--mesh", nargs="+", default=["single", "multi"])
    ap.add_argument("--arch", nargs="+", default=ARCHS)
    ap.add_argument("--shape", nargs="+", default=list(SHAPES))
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    results = []
    for arch in args.arch:
        for shape in args.shape:
            for mesh in args.mesh:
                tag = f"{arch}__{shape}__{mesh}"
                rules = cell_rules(arch, shape)
                path = os.path.join(args.out, f"{arch}__{shape}__{mesh}__{rules}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag} (exists)")
                    continue
                t0 = time.time()
                proc = subprocess.run(
                    cell_cmd(arch, shape, mesh, args.out),
                    capture_output=True,
                    text=True,
                    timeout=args.timeout,
                    env={**os.environ, "PYTHONPATH": "src"},
                    cwd=os.path.dirname(os.path.dirname(os.path.dirname(
                        os.path.dirname(os.path.abspath(__file__))))),
                )
                dt = time.time() - t0
                ok = proc.returncode == 0
                line = proc.stdout.strip().splitlines()
                summary = line[0] if line else proc.stderr.strip().splitlines()[-1:]
                print(f"[{'ok' if ok else 'FAIL'}] {tag} ({dt:.0f}s) {summary}")
                if not ok:
                    err_path = os.path.join(args.out, f"{tag}.err")
                    with open(err_path, "w") as f:
                        f.write(proc.stdout + "\n---\n" + proc.stderr)
                results.append({"tag": tag, "ok": ok, "seconds": round(dt, 1)})
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(results, f, indent=1)
    fails = [r for r in results if not r["ok"]]
    print(f"\n{len(results) - len(fails)}/{len(results)} cells ok")
    return 1 if fails else 0


if __name__ == "__main__":
    raise SystemExit(main())
