"""Training launcher.

Runs real steps on the local device(s) (CPU container / single TPU host)
or, with --dryrun, defers to repro.launch.dryrun for the production mesh.
Integrates the paper's technique end-to-end: erasure-coded checkpoints
every --ckpt-every steps, fault-tolerance manager hooks, restart-safe
synthetic data stream.

  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --smoke \
      --steps 50 --batch 4 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke
from repro.train import (
    AdamWConfig,
    DataConfig,
    ScheduleConfig,
    SyntheticStream,
    TrainConfig,
    init_train_state,
    make_train_step,
)
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import FaultToleranceManager


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="wsd", choices=["wsd", "cosine", "constant"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-code", default="DRC:9:6:3", help="family:n:k:r")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(
        optimizer=AdamWConfig(state_dtype=cfg.opt_state_dtype),
        schedule=ScheduleConfig(
            kind=args.schedule, peak_lr=args.lr, total_steps=args.steps,
            warmup_steps=max(2, args.steps // 20),
        ),
        microbatches=args.microbatches,
    )
    params, opt, _ = init_train_state(jax.random.key(args.seed), cfg, tcfg)
    stream = SyntheticStream(cfg, DataConfig(seed=args.seed, batch=args.batch, seq=args.seq))
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))

    mgr = None
    start = 0
    if args.ckpt_dir:
        fam, n, k, r = args.ckpt_code.split(":")
        mgr = CheckpointManager(args.ckpt_dir, family=fam, n=int(n), k=int(k), r=int(r))
        if args.resume and mgr.steps():
            state = {"params": params, "opt": opt}
            state, start, report = mgr.load(state)
            params, opt = state["params"], state["opt"]
            print(f"[train] resumed from step {start} (restore mode={report.mode})")

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = stream.batch_at(step)
        params, opt, metrics = step_fn(params, opt, batch, step)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            tok_s = (step - start + 1) * args.batch * args.seq / max(dt, 1e-9)
            print(
                f"[train] step={step} loss={losses[-1]:.4f} "
                f"lr={float(metrics['lr']):.2e} gnorm={float(metrics['grad_norm']):.3f} "
                f"tok/s={tok_s:.0f}"
            )
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt})
            print(f"[train] erasure-coded checkpoint @ step {step + 1}")
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt})
    ok = np.isfinite(losses).all() and losses[-1] < losses[0] + 1e-6
    print(f"[train] done: first={losses[0]:.4f} last={losses[-1]:.4f} "
          f"{'(improved)' if losses[-1] < losses[0] else ''}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
