import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Loop-aware roofline extraction (probe-and-extrapolate).

XLA's cost_analysis counts a while-loop body ONCE, independent of trip
count (verified: a 30-layer scanned stack reports the same FLOPs as a
1-layer stack; doubling microbatches halves reported FLOPs).  The full-
depth dry-run artifacts therefore prove *compile + memory fit*, but
their raw cost numbers undercount scanned programs by ~L×.

This tool recovers true per-step costs by lowering tiny probe variants
and extrapolating the exact linear structure of the program:

  train:   cost(L, mb) = base+opt(L) + fb(L)      [fb counted 1/mb per
           iteration => probes at mb=1 and mb=2 separate fb from opt]
           A=(L1,mb1) B=(L2,mb1) C=(L1,mb2) D=(L2,mb2)
           fb(1)=2(A-C), fb(2)=2(B-D), fb(L)=fb1+(L-1)(fb2-fb1)
           opt+base(L) = (A-fb1) + (L-1)[(B-fb2)-(A-fb1)]
  decode/prefill: cost(L) = A + (L-1)(B-A)

Applied identically to FLOPs, HBM bytes and each collective-op byte
bucket (collectives inside loop bodies appear once in the compiled text,
matching the same linear model).  Heterogeneous stacks get structure-
aware probes: zamba2 probes pure-Mamba and Mamba+shared-attention
variants to separate the two block costs; whisper scales encoder and
decoder depth together; xlstm is python-unrolled so the full program is
already exact.

  PYTHONPATH=src python -m repro.launch.roofline_probe --arch all \
      --out artifacts/roofline
"""
import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp

from repro.configs import applicable_shapes, get_config, input_specs
from repro.dist.sharding import axis_rules, make_rules
from repro.launch.dryrun import (
    _batch_axes,
    _named,
    abstract_decode_state,
    abstract_train_state,
    model_flops_global,
)
from repro.launch.hlo_analysis import (
    COLLECTIVE_OPS,
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    parse_collectives,
)
from repro.launch.mesh import make_production_mesh
from repro.launch.orchestrate_dryrun import OVERRIDES, cell_rules
from repro.models.common import spec as axspec
from repro.models.config import SHAPES
from repro.serve.serve_step import make_decode_step, make_prefill_step
from repro.train import TrainConfig, make_train_step
from repro.train.optimizer import AdamWConfig, opt_state_axes


def _weight_dims(cfg) -> set:
    dims = {
        cfg.d_model,
        cfg.d_ff,
        cfg.n_heads * cfg.head_dim,
        cfg.n_kv_heads * cfg.head_dim,
        cfg.padded_vocab,
        4 * cfg.d_model,
    }
    if cfg.moe:
        dims |= {cfg.moe.num_experts, cfg.moe.d_ff_expert}
    if cfg.ssm:
        d_in = cfg.d_model * cfg.ssm.expand
        dims |= {d_in, 2 * d_in, 2 * cfg.ssm.d_state, d_in // cfg.ssm.head_dim}
    # shards of those dims on a 16-way axis (weights arrive pre-sharded)
    dims |= {d // s for d in list(dims) for s in (2, 4, 8, 16) if d % s == 0}
    dims.discard(0)
    return dims


def _cost_vector(compiled, cfg) -> dict:
    ca = compiled.cost_analysis()
    st = parse_collectives(compiled.as_text(), weight_dims=_weight_dims(cfg))
    vec = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }
    for op in COLLECTIVE_OPS:
        vec[f"coll_{op}"] = float(st.bytes_by_op.get(op, 0))
        vec[f"wcoll_{op}"] = float(st.weight_bytes_by_op.get(op, 0))
    return vec


def _vec_op(a, b, f):
    return {k: f(a[k], b[k]) for k in a}


def _compile_cost(cfg, shape, mesh, rules, mb) -> dict:
    tcfg = TrainConfig(
        optimizer=AdamWConfig(state_dtype=cfg.opt_state_dtype),
        microbatches=mb,
    )
    batch = input_specs(cfg, shape)
    with axis_rules(rules), jax.set_mesh(mesh):
        if shape.kind == "train":
            ps, osh, pax = abstract_train_state(cfg, tcfg)
            p_sh = _named(mesh, pax, ps)
            o_sh = _named(mesh, opt_state_axes(pax), osh)
            b_sh = _named(mesh, _batch_axes(batch), batch)
            from repro.dist.sharding import resolve_specs

            comp = jax.jit(
                make_train_step(cfg, tcfg, param_specs=resolve_specs(pax, ps, mesh)),
                in_shardings=(p_sh, o_sh, b_sh, None),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            ).lower(ps, osh, batch, jax.ShapeDtypeStruct((), jnp.int32)).compile()
        elif shape.kind == "prefill":
            ps, _, pax = abstract_train_state(cfg, tcfg)
            p_sh = _named(mesh, pax, ps)
            b_sh = _named(mesh, _batch_axes(batch), batch)
            comp = jax.jit(
                make_prefill_step(cfg), in_shardings=(p_sh, b_sh)
            ).lower(ps, batch).compile()
        else:
            ps, _, pax = abstract_train_state(cfg, tcfg)
            p_sh = _named(mesh, pax, ps)
            ss, sax = abstract_decode_state(cfg, shape.global_batch, shape.seq_len)
            s_sh = _named(mesh, sax, ss)
            tok_sh = _named(
                mesh, {"t": axspec("batch", None)}, {"t": batch["tokens"]}
            )["t"]
            comp = jax.jit(
                make_decode_step(cfg),
                in_shardings=(p_sh, s_sh, tok_sh, None),
                out_shardings=(None, s_sh),
                donate_argnums=(1,),
            ).lower(
                ps, ss, batch["tokens"], jax.ShapeDtypeStruct((), jnp.int32)
            ).compile()
    return _cost_vector(comp, cfg)


def _probe_depths(cfg):
    """(probe_cfg_fn, units_total): returns cfg at depth u and the true
    number of repeating units for extrapolation."""
    if cfg.family == "hybrid":
        # separate pure-mamba and mamba+shared unit costs
        return None  # handled specially
    if cfg.family == "audio":
        return (
            lambda u: dataclasses.replace(
                cfg, n_layers=u, encoder_layers=u, scan_layers=False
            ),
            cfg.n_layers,
        )
    return (
        lambda u: dataclasses.replace(cfg, n_layers=u, scan_layers=False),
        cfg.n_layers,
    )


def true_costs(arch: str, shape_name: str, rules_mode: str, mb: int, mesh) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rules = make_rules(rules_mode)

    def probes(make_cfg, units):
        if shape.kind == "train":
            a = _compile_cost(make_cfg(1), shape, mesh, rules, 1)
            b = _compile_cost(make_cfg(2), shape, mesh, rules, 1)
            c = _compile_cost(make_cfg(1), shape, mesh, rules, 2)
            d = _compile_cost(make_cfg(2), shape, mesh, rules, 2)
            fb1 = _vec_op(a, c, lambda x, y: 2 * (x - y))
            fb2 = _vec_op(b, d, lambda x, y: 2 * (x - y))
            ob1 = _vec_op(a, fb1, lambda x, y: x - y)
            ob2 = _vec_op(b, fb2, lambda x, y: x - y)
            out = {}
            for k in a:
                fb = fb1[k] + (units - 1) * (fb2[k] - fb1[k])
                ob = ob1[k] + (units - 1) * (ob2[k] - ob1[k])
                # weight-shaped collectives (FSDP gathers, grad
                # reductions) recur once per microbatch; everything else
                # scales with tokens (mb-invariant per step)
                scale = mb if k.startswith("wcoll_") else 1.0
                out[k] = max(0.0, fb + scale * ob)
            return out
        a = _compile_cost(make_cfg(1), shape, mesh, rules, 1)
        b = _compile_cost(make_cfg(2), shape, mesh, rules, 1)
        return {k: max(0.0, a[k] + (units - 1) * (b[k] - a[k])) for k in a}

    if cfg.family == "ssm":  # python-unrolled: the full program is exact
        return _compile_cost(cfg, shape, mesh, rules, 1)
    if cfg.family == "hybrid":
        pure = lambda u: dataclasses.replace(
            cfg, n_layers=u, shared_attn_every=0, scan_layers=False
        )
        mixed = lambda u: dataclasses.replace(
            cfg, n_layers=u, shared_attn_every=1, scan_layers=False
        )
        n_shared = cfg.n_layers // (cfg.shared_attn_every or cfg.n_layers)
        pm = probes(pure, cfg.n_layers)  # base + 38 mamba units
        mm = probes(mixed, cfg.n_layers)  # base + 38 (mamba+shared) units
        # shared-block marginal per unit = (mm - pm)/units; true adds n_shared
        out = {}
        for k in pm:
            shared_unit = (mm[k] - pm[k]) / cfg.n_layers
            out[k] = max(0.0, pm[k] + n_shared * shared_unit)
        return out
    make_cfg, units = _probe_depths(cfg)
    return probes(make_cfg, units)


def roofline_terms(arch, shape_name, costs, n_devices):
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    coll = sum(
        v for k, v in costs.items() if k.startswith(("coll_", "wcoll_"))
    )
    comp = costs["flops"] / PEAK_FLOPS_BF16
    mem = costs["bytes"] / HBM_BW
    cx = coll / ICI_BW
    terms = {"compute": comp, "memory": mem, "collective": cx}
    model = model_flops_global(cfg, shape, shape.kind) / n_devices
    by_op = {}
    for k, v in costs.items():
        if k.startswith("coll_"):
            by_op[k[5:]] = by_op.get(k[5:], 0.0) + v
        elif k.startswith("wcoll_"):
            by_op["w:" + k[6:]] = v
    return {
        "flops": costs["flops"],
        "hbm_bytes": costs["bytes"],
        "collective_bytes": coll,
        "collective_by_op": by_op,
        "weight_collective_bytes": sum(
            v for k, v in costs.items() if k.startswith("wcoll_")
        ),
        "compute_s": comp,
        "memory_s": mem,
        "collective_s": cx,
        "bottleneck": max(terms, key=terms.get),
        "model_flops": model,
        "useful_flops_ratio": model / costs["flops"] if costs["flops"] else 0.0,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="+", default=["all"])
    ap.add_argument("--shape", nargs="+", default=list(SHAPES))
    ap.add_argument("--out", default="artifacts/roofline")
    ap.add_argument("--rules", default=None)
    ap.add_argument("--mb", type=int, default=None)
    args = ap.parse_args()
    from repro.configs import ARCHS

    archs = ARCHS if args.arch == ["all"] else args.arch
    os.makedirs(args.out, exist_ok=True)
    mesh = make_production_mesh()
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in args.shape:
            if shape_name not in applicable_shapes(cfg):
                continue
            rules_mode = args.rules or cell_rules(arch, shape_name)
            ov = OVERRIDES.get((arch, shape_name), [])
            mb = args.mb or (
                int(ov[ov.index("--microbatches") + 1])
                if "--microbatches" in ov
                else 1
            )
            try:
                costs = true_costs(arch, shape_name, rules_mode, mb, mesh)
                roof = roofline_terms(arch, shape_name, costs, mesh.size)
                res = {
                    "arch": arch,
                    "shape": shape_name,
                    "rules": rules_mode,
                    "microbatches": mb,
                    "status": "ok",
                    "roofline": roof,
                }
            except Exception as e:  # record and continue
                res = {"arch": arch, "shape": shape_name, "status": "error",
                       "error": repr(e)[:200]}
            tag = f"{arch}__{shape_name}"
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(res, f, indent=1)
            if res["status"] == "ok":
                r = res["roofline"]
                print(
                    f"[probe] {arch} x {shape_name} ({rules_mode}): "
                    f"terms(c/m/x)=({r['compute_s']:.4f},{r['memory_s']:.4f},"
                    f"{r['collective_s']:.4f})s bottleneck={r['bottleneck']} "
                    f"useful={r['useful_flops_ratio']:.2f}"
                )
            else:
                print(f"[probe] {arch} x {shape_name}: ERROR {res['error']}")


if __name__ == "__main__":
    main()
