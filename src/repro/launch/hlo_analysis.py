"""Post-compile HLO analysis: collective bytes + roofline terms.

`compiled.cost_analysis()` gives FLOPs and HBM bytes but not collective
traffic, so we parse the compiled module text: every line of the form

    %name = <shape> <collective-op>(...)

contributes its result-shape bytes to that op's bucket.  Shapes can be
tuples (all-reduce with N operands); each element is counted.  The
roofline terms then follow DESIGN.md §7 / the brief:

    compute    = HLO_FLOPs / peak_FLOPs
    memory     = HLO_bytes / HBM_bw
    collective = collective_bytes / link_bw

cost_analysis of an SPMD-partitioned module reports *per-device*
numbers, and collective result shapes are also per-device, so all three
terms are per-chip seconds directly (equivalent to the brief's
global/(chips·BW) form).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_PAIRS_RE = re.compile(r"source_target_pairs=\{(\{[0-9]+,[0-9]+\}(?:,\{[0-9]+,[0-9]+\})*)\}")
_PAIR_RE = re.compile(r"\{([0-9]+),([0-9]+)\}")


@dataclass(frozen=True)
class PermuteInstr:
    """One compiled ``collective-permute`` instruction: per-device result
    bytes plus its ``source_target_pairs`` (flat *device* ids)."""

    nbytes: int
    pairs: tuple[tuple[int, int], ...]


def parse_permutes(hlo_text: str) -> list[PermuteInstr]:
    """Every collective-permute of a compiled module, with its device
    pairing — the raw material for cross-pod byte accounting."""
    out: list[PermuteInstr] = []
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        m = re.search(r"=\s+(.+?)\s+collective-permute(?:-start)?\(", line)
        if m is None or "collective-permute-done(" in line:
            continue
        pm = _PAIRS_RE.search(line)
        pairs: tuple[tuple[int, int], ...] = ()
        if pm:
            pairs = tuple(
                (int(s), int(d)) for s, d in _PAIR_RE.findall(pm.group(1))
            )
        out.append(PermuteInstr(nbytes=_shape_bytes(m.group(1)), pairs=pairs))
    return out


def cross_pod_permute_bytes(hlo_text: str, w: int) -> int:
    """Bytes the compiled module ships *across pods* via
    collective-permute, with pod = device // w on a (pod, node) mesh.

    Result shapes are per-device shards, so each instruction whose
    pairing crosses a pod boundary contributes its result bytes once —
    the same accounting that makes the sum comparable to
    ``plan.traffic_blocks()["cross_rack_blocks"] * alpha * sub``.
    """
    total = 0
    for instr in parse_permutes(hlo_text):
        if any(s // w != d // w for s, d in instr.pairs):
            total += instr.nbytes
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict[str, int] = field(default_factory=dict)
    count_by_op: dict[str, int] = field(default_factory=dict)
    weight_bytes_by_op: dict[str, int] = field(default_factory=dict)
    largest: list[tuple[str, int]] = field(default_factory=list)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values()) + sum(
            self.weight_bytes_by_op.values()
        )


def _dims_of(text: str) -> list[tuple[int, ...]]:
    return [
        tuple(int(d) for d in dims.split(",")) if dims else ()
        for _, dims in _SHAPE_RE.findall(text)
    ]


def parse_collectives(hlo_text: str, weight_dims: set | None = None) -> CollectiveStats:
    """weight_dims: dims whose presence in *every* axis of a 2-D/3-D shape
    classifies the op as weight movement (FSDP gathers / grad reductions),
    which scales with microbatch count rather than token count."""
    stats = CollectiveStats()
    sizes: list[tuple[str, int]] = []
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        for op in COLLECTIVE_OPS:
            # match " <op>(" after the result shape, not inside metadata
            m = re.search(rf"=\s+(.+?)\s+{op}(?:-start|-done)?\(", line)
            if m:
                if f"{op}-done(" in line:
                    break  # paired with -start; avoid double counting
                shape_txt = m.group(1)
                b = _shape_bytes(shape_txt)
                is_weight = False
                if weight_dims:
                    dims_list = [d for d in _dims_of(shape_txt) if d]
                    is_weight = bool(dims_list) and all(
                        2 <= len(d) <= 3 and all(x in weight_dims for x in d)
                        for d in dims_list
                    )
                bucket = stats.weight_bytes_by_op if is_weight else stats.bytes_by_op
                bucket[op] = bucket.get(op, 0) + b
                stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
                sizes.append((op, b))
                break
    stats.largest = sorted(sizes, key=lambda t: -t[1])[:8]
    return stats


@dataclass
class Roofline:
    flops: float  # per-device HLO flops
    hbm_bytes: float  # per-device bytes accessed
    collective_bytes: float  # per-device collective bytes
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float  # analytic 6·N·D (or decode equivalent), per device
    useful_flops_ratio: float

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def roofline_from(compiled, model_flops_global: float, n_devices: int) -> tuple[Roofline, CollectiveStats]:
    ca = compiled.cost_analysis()
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    stats = parse_collectives(compiled.as_text())
    comp = flops / PEAK_FLOPS_BF16
    mem = hbm / HBM_BW
    coll = stats.total_bytes / ICI_BW
    terms = {"compute": comp, "memory": mem, "collective": coll}
    model_per_dev = model_flops_global / n_devices
    return (
        Roofline(
            flops=flops,
            hbm_bytes=hbm,
            collective_bytes=stats.total_bytes,
            compute_s=comp,
            memory_s=mem,
            collective_s=coll,
            bottleneck=max(terms, key=terms.get),
            model_flops=model_per_dev,
            useful_flops_ratio=(model_per_dev / flops) if flops else 0.0,
        ),
        stats,
    )
