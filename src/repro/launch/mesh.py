"""Production meshes.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.  The single-pod mesh is one
16x16 v5e pod (256 chips); the multi-pod mesh adds a leading "pod" axis
(2 pods = 512 chips) — the paper's rack analogue (DESIGN.md §2).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_repair_mesh(r: int, w: int):
    """Mesh for the layered-repair SPMD program: r pods x w nodes."""
    return jax.make_mesh(
        (r, w), ("pod", "node"), axis_types=(jax.sharding.AxisType.Auto,) * 2
    )


# Hardware constants (TPU v5e) for the roofline terms.
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
