import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Largest-buffer forensics for a dry-run cell (perf-iteration tool).

Prints the biggest per-device HLO buffers grouped by (shape, op) — the
first stop when a cell's memory_analysis exceeds the 16 GiB v5e budget.

  PYTHONPATH=src python -m repro.launch.buffers --arch grok-1-314b \
      --shape train_4k --rules fsdp_sp --microbatches 4
"""
import argparse
import re
from collections import Counter

import numpy as np

_BY = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
       "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}
_PAT = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]+)\]")


def top_buffers(hlo_text: str, min_bytes: int = 2**27, top: int = 20):
    agg = Counter()
    for line in hlo_text.splitlines():
        line = line.strip()
        if " = " not in line:
            continue
        rhs = line.split(" = ", 1)[1]
        m = _PAT.match(rhs)
        if not m:
            continue
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            n *= int(d)
        b = n * _BY[dt]
        if b >= min_bytes:
            op = rhs.split("(")[0].split()[-1]
            agg[(f"{dt}[{dims}]", op, b)] += 1
    rows = sorted(agg.items(), key=lambda kv: -kv[0][2] * kv[1])[:top]
    return [
        {"shape": s, "op": op, "gib": round(b / 2**30, 3), "count": c,
         "total_gib": round(b * c / 2**30, 2)}
        for (s, op, b), c in rows
    ]


def main():
    from repro.launch import dryrun as dr

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--rules", default="tp_sp")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--accum-dtype", default="float32")
    args = ap.parse_args()

    # monkey-patch run_cell's compile step to capture hlo? simpler: rebuild
    import jax

    from repro.configs import get_config, input_specs
    from repro.dist.sharding import axis_rules, make_rules
    from repro.launch.mesh import make_production_mesh
    from repro.models.config import SHAPES
    from repro.train import TrainConfig, make_train_step
    from repro.train.optimizer import AdamWConfig, opt_state_axes

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    rules = make_rules(args.rules, multi_pod=args.mesh == "multi")
    tcfg = TrainConfig(
        optimizer=AdamWConfig(state_dtype=cfg.opt_state_dtype),
        microbatches=args.microbatches,
        accum_dtype=args.accum_dtype,
    )
    batch_shapes = input_specs(cfg, shape)
    with axis_rules(rules), jax.set_mesh(mesh):
        if shape.kind == "train":
            pshapes, oshapes, paxes = dr.abstract_train_state(cfg, tcfg)
            p_sh = dr._named(mesh, paxes, pshapes)
            o_sh = dr._named(mesh, opt_state_axes(paxes), oshapes)
            b_sh = dr._named(mesh, dr._batch_axes(batch_shapes), batch_shapes)
            jitted = jax.jit(
                make_train_step(cfg, tcfg),
                in_shardings=(p_sh, o_sh, b_sh, None),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            )
            comp = jitted.lower(
                pshapes, oshapes, batch_shapes,
                jax.ShapeDtypeStruct((), jax.numpy.int32),
            ).compile()
        else:
            from repro.serve.serve_step import make_decode_step
            from repro.models.common import spec as axspec

            pshapes, _, paxes = dr.abstract_train_state(cfg, tcfg)
            p_sh = dr._named(mesh, paxes, pshapes)
            sshapes, saxes = dr.abstract_decode_state(
                cfg, shape.global_batch, shape.seq_len
            )
            s_sh = dr._named(mesh, saxes, sshapes)
            tok_sh = dr._named(
                mesh, {"t": axspec("batch", None)},
                {"t": batch_shapes["tokens"]},
            )["t"]
            jitted = jax.jit(
                make_decode_step(cfg),
                in_shardings=(p_sh, s_sh, tok_sh, None),
                out_shardings=(None, s_sh),
                donate_argnums=(1,),
            )
            comp = jitted.lower(
                pshapes, sshapes, batch_shapes["tokens"],
                jax.ShapeDtypeStruct((), jax.numpy.int32),
            ).compile()
    for row in top_buffers(comp.as_text()):
        print(row)


if __name__ == "__main__":
    main()
