"""Repair-plan engine — the paper's DoubleR workflow (§2.2, §5.2) as data.

A `RepairPlan` is an explicit, executable DAG mirroring DoubleR's three
exported APIs:

* ``NodeEncode``   — each helper node applies a small GF matrix to its own
                     α subblocks and ships the resulting units.
* ``RelayerEncode``— one relayer per non-local rack re-encodes [its own
                     subblocks ++ units received from rack-mates] and ships
                     the result cross-rack to the target.
* ``Decode``       — the target applies the decode matrix to every unit it
                     received (local units ++ relayer units ++ any direct
                     cross-rack units for non-layered codes).

Plans carry exact GF(256) matrices, so they are simultaneously

  (a) executable against real payload bytes (numpy or JAX path),
  (b) verifiable symbolically (propagate coefficient vectors; the decode
      matrix must reproduce the failed node's generator rows), and
  (c) the source of truth for bandwidth accounting (inner- vs cross-rack
      bytes, per-relayer balance) used by the analysis/benchmarks.

Unit = one subblock payload of B/α bytes; bandwidth is reported in *blocks*.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro import obs
from repro.check.errors import PlanError

from . import gf
from .placement import Placement

TARGET = -1  # pseudo destination id for the reconstruction target


@dataclass(frozen=True)
class Send:
    """One directed transfer of `matrix.shape[0]` units."""

    src: int
    dst: int  # a relayer node id, or TARGET
    matrix: np.ndarray  # (units, input_dim) over GF(256)

    def __post_init__(self) -> None:
        m = self.matrix
        where = f"Send {self.src}->{self.dst}"
        if not isinstance(m, np.ndarray) or m.ndim != 2:
            raise PlanError(
                f"{where}: matrix must be a 2-D ndarray, got "
                f"{type(m).__name__} ndim={getattr(m, 'ndim', None)}",
                rule="plan.dag.send-matrix", src=self.src, dst=self.dst,
            )
        if m.dtype != np.uint8:
            raise PlanError(
                f"{where}: matrix must be uint8 over GF(256), got {m.dtype}",
                rule="plan.dag.send-matrix", src=self.src, dst=self.dst,
                dtype=str(m.dtype),
            )
        if m.shape[1] == 0:
            raise PlanError(
                f"{where}: matrix has no input columns (shape {m.shape}) — "
                f"the sender would combine zero subblocks",
                rule="plan.dag.send-matrix", src=self.src, dst=self.dst,
                shape=m.shape,
            )

    @property
    def units(self) -> int:
        return self.matrix.shape[0]


@dataclass
class RepairPlan:
    """Executable repair of one failed node (paper Fig. 1)."""

    failed: int
    placement: Placement
    alpha: int
    node_sends: list[Send]  # NodeEncode: input_dim == alpha (own subblocks)
    relayer_sends: list[Send]  # RelayerEncode: input = own subblocks ++ received
    decode: np.ndarray  # (alpha, total units at target)
    # provenance of the target's input units, in decode-column order:
    target_order: list[int] = field(default_factory=list)  # src node per unit

    # ------------------------------------------------------------------ util
    def _relayer_input_order(self, relayer: int) -> list[Send]:
        """Units entering a relayer, in canonical order (after its own rows)."""
        return sorted(
            (s for s in self.node_sends if s.dst == relayer), key=lambda s: s.src
        )

    @property
    def relayers(self) -> list[int]:
        return sorted({s.src for s in self.relayer_sends})

    # ------------------------------------------------------------ accounting
    def traffic_blocks(self) -> dict[str, Any]:
        """Inner-/cross-rack repair traffic in units of blocks (B = 1);
        ``per_relayer_cross`` is a nested {relayer: blocks} map."""
        rack = self.placement.rack_of
        target_rack = rack(self.failed)
        inner = 0.0
        cross = 0.0
        per_relayer_cross: dict[int, float] = {}
        for s in self.node_sends:
            dst_rack = target_rack if s.dst == TARGET else rack(s.dst)
            size = s.units / self.alpha
            if rack(s.src) == dst_rack:
                inner += size
            else:
                cross += size
        for s in self.relayer_sends:
            size = s.units / self.alpha
            if rack(s.src) == target_rack:
                inner += size
            else:
                cross += size
                per_relayer_cross[s.src] = per_relayer_cross.get(s.src, 0.0) + size
        return {
            "inner_rack_blocks": inner,
            "cross_rack_blocks": cross,
            "per_relayer_cross": per_relayer_cross,
            "total_blocks": inner + cross,
        }

    def relayer_io_blocks(self, relayer: int) -> tuple[float, float]:
        """(units received from rack-mates, units sent cross-rack), in blocks."""
        recv = sum(s.units for s in self.node_sends if s.dst == relayer) / self.alpha
        sent = sum(s.units for s in self.relayer_sends if s.src == relayer) / self.alpha
        return recv, sent

    # ---------------------------------------------------------- verification
    def coefficient_check(self, node_coeffs: list[np.ndarray]) -> bool:
        """Symbolic correctness: decode @ (target unit coeffs) == G_failed.

        node_coeffs[i]: (alpha, k*alpha) coefficient rows of node i's
        subblocks in terms of the data subsymbols.
        """
        unit_coeffs = self._target_unit_coeffs(node_coeffs)
        got = gf.gf_matmul(self.decode, unit_coeffs)
        return bool(np.array_equal(got, node_coeffs[self.failed]))

    def _target_unit_coeffs(self, node_coeffs: list[np.ndarray]) -> np.ndarray:
        sent_coeffs: dict[tuple[int, int], np.ndarray] = {}
        for s in self.node_sends:
            sent_coeffs[(s.src, s.dst)] = gf.gf_matmul(s.matrix, node_coeffs[s.src])
        rows: list[np.ndarray] = []
        order: list[int] = []
        for s in sorted(
            (x for x in self.node_sends if x.dst == TARGET), key=lambda x: x.src
        ):
            rows.append(sent_coeffs[(s.src, TARGET)])
            order.extend([s.src] * s.units)
        for s in sorted(self.relayer_sends, key=lambda x: x.src):
            inputs = [node_coeffs[s.src]]
            for ns in self._relayer_input_order(s.src):
                inputs.append(sent_coeffs[(ns.src, s.src)])
            rows.append(gf.gf_matmul(s.matrix, np.concatenate(inputs, axis=0)))
            order.extend([s.src] * s.units)
        if order != self.target_order:
            raise PlanError(
                f"target order mismatch: canonical {order} vs recorded "
                f"{self.target_order}",
                rule="plan.dag.target-order",
                canonical=order, recorded=list(self.target_order),
                failed=self.failed,
            )
        return np.concatenate(rows, axis=0)

    # ---------------------------------------------------------- observability
    def _record_send(self, s: Send, sub_bytes: int, stage: str) -> None:
        """Book one transfer into the obs counters.

        Classification (inner vs cross rack) is intentionally the same
        rule as `traffic_blocks`, so traced byte counters cross-check
        exactly against the plan's symbolic bandwidth accounting:
        bytes == blocks * alpha * sub_bytes.
        """
        rack = self.placement.rack_of
        dst_rack = rack(self.failed) if s.dst == TARGET else rack(s.dst)
        scope = "inner" if rack(s.src) == dst_rack else "cross"
        nbytes = s.units * sub_bytes
        obs.counter_add(f"repair.bytes.{scope}_rack", nbytes, stage=stage)
        if stage == "relayer_encode" and scope == "cross":
            obs.counter_add("repair.units_cross", s.units, relayer=str(s.src))

    # ------------------------------------------------------------- execution
    def execute(self, payloads: dict[int, np.ndarray]) -> np.ndarray:
        """Run the plan on real bytes.

        payloads: node id -> (alpha, sub_bytes) uint8 for every surviving
        helper the plan references.  Returns the reconstructed (alpha,
        sub_bytes) payload of the failed node.

        Under an active `repro.obs` tracer, every NodeEncode /
        RelayerEncode / Decode gets a span and the bytes each transfer
        moves are counted inner- vs cross-rack (see `_record_send`).
        """
        sub_bytes = next(iter(payloads.values())).shape[1]
        with obs.span("repair.execute", cat="repair", failed=self.failed,
                      alpha=self.alpha, sub_bytes=sub_bytes):
            sent: dict[tuple[int, int], np.ndarray] = {}
            for s in self.node_sends:
                with obs.span("repair.node_encode", cat="repair", src=s.src,
                              dst=s.dst, units=s.units):
                    sent[(s.src, s.dst)] = gf.gf_matmul(s.matrix, payloads[s.src])
                    obs.counter_add(
                        "repair.gf_mult_bytes",
                        int(np.count_nonzero(s.matrix)) * sub_bytes,
                        stage="node_encode",
                    )
                self._record_send(s, sub_bytes, "node_encode")
            units: list[np.ndarray] = []
            for s in sorted(
                (x for x in self.node_sends if x.dst == TARGET), key=lambda x: x.src
            ):
                units.append(sent[(s.src, TARGET)])
            for s in sorted(self.relayer_sends, key=lambda x: x.src):
                with obs.span("repair.relayer_encode", cat="repair",
                              relayer=s.src, units=s.units):
                    inputs = [payloads[s.src]]
                    for ns in self._relayer_input_order(s.src):
                        inputs.append(sent[(ns.src, s.src)])
                    units.append(
                        gf.gf_matmul(s.matrix, np.concatenate(inputs, axis=0))
                    )
                    obs.counter_add(
                        "repair.gf_mult_bytes",
                        int(np.count_nonzero(s.matrix)) * sub_bytes,
                        stage="relayer_encode",
                    )
                self._record_send(s, sub_bytes, "relayer_encode")
            with obs.span("repair.decode", cat="repair",
                          units=self.decode.shape[1]):
                target_in = np.concatenate(units, axis=0)
                obs.counter_add(
                    "repair.gf_mult_bytes",
                    int(np.count_nonzero(self.decode)) * sub_bytes,
                    stage="decode",
                )
                return gf.gf_matmul(self.decode, target_in)

    def participants(self) -> list[int]:
        return sorted(
            {s.src for s in self.node_sends} | {s.src for s in self.relayer_sends}
        )


def build_target_order(plan_sends: list[Send], relayer_sends: list[Send]) -> list[int]:
    order: list[int] = []
    for s in sorted((x for x in plan_sends if x.dst == TARGET), key=lambda x: x.src):
        order.extend([s.src] * s.units)
    for s in sorted(relayer_sends, key=lambda x: x.src):
        order.extend([s.src] * s.units)
    return order
