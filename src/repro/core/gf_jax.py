"""GF(2^8) data-path operations in JAX.

Plan-time linear algebra lives in `repro.core.gf` (numpy).  This module
executes the resulting matrices against real payload bytes as jitted JAX ops.
Two interchangeable execution paths:

* ``gf_matmul_jnp`` — pure-jnp mul-table gather + XOR reduce (oracle; runs
  everywhere, used by tests and small payloads).
* ``repro.kernels.ops.gf_matmul`` — Pallas TPU kernel (bitplane MXU matmul);
  validated against this module in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import gf as _gf

# Device-resident constant tables.
MUL_TABLE = jnp.asarray(_gf.GF_MUL_TABLE)  # (256,256) uint8


@jax.jit
def gf_mul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Element-wise GF(256) product of uint8 arrays (broadcasting)."""
    a = a.astype(jnp.uint8)
    b = b.astype(jnp.uint8)
    return MUL_TABLE[a.astype(jnp.int32), b.astype(jnp.int32)]


@jax.jit
def gf_matmul_jnp(m: jax.Array, x: jax.Array) -> jax.Array:
    """GF(256) matrix product (rows, k) @ (k, payload) -> (rows, payload).

    XOR-accumulated table products via one gather:
      prod[r, j, p] = table[m[r, j], x[j, p]]; out[r, p] = XOR_j prod[r, j, p].

    XOR-reduce is expressed as a loop of jnp.bitwise_xor.reduce over axis 1.
    """
    m = m.astype(jnp.int32)
    x = x.astype(jnp.int32)
    prod = MUL_TABLE[m[:, :, None], x[None, :, :]]  # (rows, k, payload) uint8
    return jax.lax.reduce(
        prod,
        jnp.uint8(0),
        lambda a, b: jnp.bitwise_xor(a, b),
        dimensions=(1,),
    )


def gf_matvec_bytes(m: np.ndarray | jax.Array, x: jax.Array) -> jax.Array:
    """Apply a plan-time GF matrix to stacked byte payloads.

    x: (k, payload_bytes) uint8; m: (rows, k) uint8 -> (rows, payload_bytes).
    """
    m = jnp.asarray(np.asarray(m, dtype=np.uint8))
    return gf_matmul_jnp(m, x)


@functools.partial(jax.jit, static_argnames=("axis",))
def xor_reduce(x: jax.Array, axis: int = 0) -> jax.Array:
    return jax.lax.reduce(
        x, jnp.uint8(0), lambda a, b: jnp.bitwise_xor(a, b), dimensions=(axis,)
    )


def bytes_to_bits(x: jax.Array) -> jax.Array:
    """Unpack uint8 (..., B) -> uint8 bits (..., 8, B), LSB first."""
    shifts = jnp.arange(8, dtype=jnp.uint8)
    return (x[..., None, :] >> shifts[:, None]) & jnp.uint8(1)


def bits_to_bytes(bits: jax.Array) -> jax.Array:
    """Pack uint8 bits (..., 8, B) (LSB first) -> uint8 (..., B)."""
    shifts = jnp.arange(8, dtype=jnp.uint8)
    return jnp.sum(
        (bits.astype(jnp.uint8) & 1) << shifts[:, None], axis=-2, dtype=jnp.uint8
    )
