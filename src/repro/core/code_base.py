"""Erasure-code abstraction shared by RS / MSR / DRC (paper §3–§4).

Every code is linear over GF(2^8) with subpacketization α: node i stores α
subblocks, each a GF(256)-linear combination of the k·α data subsymbols.  The
whole code is a systematic generator matrix

    G ∈ GF(256)^{nα × kα},   G[:kα] = I   (systematic, Goal 2)

plus per-failed-node `RepairPlan`s (see repro.core.repair).  Encoding,
decoding and repairing real payloads are all GF matrix products, which is
what the Pallas kernel accelerates on TPU.
"""
from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass

import numpy as np

from . import gf
from .placement import Placement
from .repair import RepairPlan


class ErasureCode:
    """Base class. Subclasses set name/n/k/alpha, build G and repair plans."""

    name: str = "base"

    def __init__(self, n: int, k: int, r: int, alpha: int):
        if not (0 < k < n):
            raise ValueError(f"need 0<k<n, got n={n} k={k}")
        self.n = n
        self.k = k
        self.alpha = alpha
        self.placement = Placement(n, r)
        self.generator = self._build_generator()
        expected = (n * alpha, k * alpha)
        if self.generator.shape != expected:
            raise ValueError(f"generator shape {self.generator.shape} != {expected}")
        if not np.array_equal(
            self.generator[: k * alpha], np.eye(k * alpha, dtype=np.uint8)
        ):
            raise ValueError("generator is not systematic")

    # -------------------------------------------------------------- virtuals
    def _build_generator(self) -> np.ndarray:
        raise NotImplementedError

    def repair_plan(self, failed: int, rotation: int = 0) -> RepairPlan:
        """`rotation` rotates relayer/helper choices across stripes
        (paper §5.2 node-recovery parallelization); codes without
        relayers may ignore it."""
        raise NotImplementedError

    # ------------------------------------------------------------ properties
    @property
    def r(self) -> int:
        return self.placement.r

    @property
    def params(self) -> tuple[int, int, int]:
        return (self.n, self.k, self.r)

    def __repr__(self) -> str:
        return f"{self.name}({self.n},{self.k},{self.r})"

    def node_coeffs(self, i: int) -> np.ndarray:
        """(alpha, k*alpha) generator rows of node i."""
        return self.generator[i * self.alpha : (i + 1) * self.alpha]

    def all_node_coeffs(self) -> list[np.ndarray]:
        return [self.node_coeffs(i) for i in range(self.n)]

    @property
    def storage_overhead(self) -> float:
        return self.n / self.k

    # ---------------------------------------------------------------- encode
    def encode(self, data: np.ndarray) -> list[np.ndarray]:
        """Encode data bytes into n node payloads.

        data: (k*alpha, sub_bytes) uint8 — k blocks split into alpha
        subblocks each.  Returns [n x (alpha, sub_bytes)].
        """
        if data.ndim != 2 or data.shape[0] != self.k * self.alpha:
            raise ValueError(f"data must be (k*alpha, sub_bytes), got {data.shape}")
        coded = gf.gf_matmul(self.generator, data)
        return [
            coded[i * self.alpha : (i + 1) * self.alpha] for i in range(self.n)
        ]

    def encode_blocks(self, blocks: np.ndarray) -> list[np.ndarray]:
        """Encode k equal-size blocks: (k, block_bytes) -> n node payloads."""
        k, bb = blocks.shape
        if k != self.k or bb % self.alpha:
            raise ValueError(f"need ({self.k}, multiple of alpha) blocks")
        data = blocks.reshape(self.k * self.alpha, bb // self.alpha)
        return self.encode(data)

    # ---------------------------------------------------------------- decode
    @functools.lru_cache(maxsize=512)
    def _decode_matrix(self, available: tuple[int, ...]) -> np.ndarray:
        """Matrix reconstructing all k*alpha data subsymbols from the stacked
        subblocks of `available` nodes (any set whose rows have full rank)."""
        rows = np.concatenate([self.node_coeffs(i) for i in available], axis=0)
        # Solve rows @ X = I  ->  want D with D @ rows = I:  D = solve(rows^T x = e)
        d = gf.gf_solve(rows.T, np.eye(self.k * self.alpha, dtype=np.uint8))
        return np.ascontiguousarray(d.T)

    def decode(self, available: dict[int, np.ndarray]) -> np.ndarray:
        """Reconstruct the (k*alpha, sub_bytes) data from >=k available nodes."""
        ids = tuple(sorted(available))
        dm = self._decode_matrix(ids)
        stacked = np.concatenate([available[i] for i in ids], axis=0)
        return gf.gf_matmul(dm, stacked)

    # ------------------------------------------------------------ validation
    def is_mds(self, exhaustive_limit: int = 512, seed: int = 0) -> bool:
        """Any k nodes must carry full-rank (k*alpha) coefficient rows."""
        combos = list(itertools.combinations(range(self.n), self.k))
        if len(combos) > exhaustive_limit:
            rng = np.random.default_rng(seed)
            combos = [
                tuple(sorted(rng.choice(self.n, size=self.k, replace=False)))
                for _ in range(exhaustive_limit)
            ]
        need = self.k * self.alpha
        for c in combos:
            rows = np.concatenate([self.node_coeffs(i) for i in c], axis=0)
            if gf.gf_rank(rows) != need:
                return False
        return True

    def verify_repair(self, failed: int) -> bool:
        plan = self.repair_plan(failed)
        return plan.coefficient_check(self.all_node_coeffs())

    # --------------------------------------------------------- repair helper
    def repair(self, failed: int, payloads: dict[int, np.ndarray]) -> np.ndarray:
        return self.repair_plan(failed).execute(payloads)

    # ------------------------------------------------- closed-form bandwidth
    def theoretical_cross_rack_blocks(self) -> float:
        """Paper Eq. (1)/(2)/(3) — overridden per family."""
        raise NotImplementedError


@dataclass(frozen=True)
class CodeSpec:
    """Registry key: code family + (n, k, r)."""

    family: str
    n: int
    k: int
    r: int

    def __str__(self) -> str:
        return f"{self.family}({self.n},{self.k},{self.r})"


def drc_min_cross_rack_blocks(n: int, k: int, r: int) -> float:
    """Paper Eq. (3): minimum cross-rack repair bandwidth, in blocks."""
    return (r - 1) / (r - (k * r) // n)


def msr_repair_blocks(n: int, k: int) -> float:
    """Paper Eq. (2): MSR total repair bandwidth (d = n-1), in blocks."""
    return (n - 1) / (n - k)


def rs_repair_blocks(k: int) -> float:
    """Paper Eq. (1), in blocks."""
    return float(k)
