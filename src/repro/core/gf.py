"""GF(2^8) arithmetic and linear algebra (plan-time, numpy).

This module is the *plan-time* arithmetic layer: repair plans, generator
matrices, interference-alignment solves and dual-codeword searches are all
small dense GF(256) linear algebra problems, computed once per (code, failed
node) and cached.  The *data path* (encoding/repairing real bytes) runs in JAX
(`repro.core.gf_jax`) and, for the hot spot, in the Pallas kernel
(`repro.kernels.gf_matmul`).

Field: GF(2^8) with the AES/ISA-L primitive polynomial x^8+x^4+x^3+x^2+1
(0x11D), generator 2 — byte-compatible with Intel ISA-L used by the paper.
"""
from __future__ import annotations

import numpy as np

PRIM_POLY = 0x11D  # x^8 + x^4 + x^3 + x^2 + 1
FIELD = 256
ORDER = FIELD - 1  # multiplicative group order


def _build_tables() -> tuple[np.ndarray, np.ndarray]:
    exp = np.zeros(2 * ORDER, dtype=np.uint8)  # doubled to skip "mod 255"
    log = np.zeros(FIELD, dtype=np.int32)
    x = 1
    for i in range(ORDER):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & 0x100:
            x ^= PRIM_POLY
    exp[ORDER:] = exp[:ORDER]
    log[0] = -1  # sentinel; never dereferenced on the zero-guarded paths
    return exp, log


GF_EXP, GF_LOG = _build_tables()

# Full 256x256 multiplication table: tiny (64 KiB) and by far the most robust
# plan-time path.  Also exported to the JAX layer.
_A, _B = np.meshgrid(np.arange(FIELD), np.arange(FIELD), indexing="ij")
GF_MUL_TABLE = np.zeros((FIELD, FIELD), dtype=np.uint8)
_nz = (_A > 0) & (_B > 0)
GF_MUL_TABLE[_nz] = GF_EXP[(GF_LOG[_A[_nz]] + GF_LOG[_B[_nz]])]

GF_INV_TABLE = np.zeros(FIELD, dtype=np.uint8)
GF_INV_TABLE[1:] = GF_EXP[ORDER - GF_LOG[np.arange(1, FIELD)]]


def gf_mul(a, b):
    """Element-wise GF(256) multiply for uint8 arrays/scalars."""
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    return GF_MUL_TABLE[a, b]


def gf_div(a, b):
    b = np.asarray(b, dtype=np.uint8)
    if np.any(b == 0):
        raise ZeroDivisionError("GF(256) division by zero")
    return gf_mul(a, GF_INV_TABLE[b])


def gf_inv(a):
    a = np.asarray(a, dtype=np.uint8)
    if np.any(a == 0):
        raise ZeroDivisionError("GF(256) inverse of zero")
    return GF_INV_TABLE[a]


def gf_pow(a: int, e: int) -> int:
    if e == 0:
        return 1
    if a == 0:
        return 0
    return int(GF_EXP[(int(GF_LOG[a]) * e) % ORDER])


def gf_matmul(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Matrix product over GF(256): (m,k) x (k,p) -> (m,p).

    XOR-accumulation of table products.  Vectorized over the output row: for
    plan-time sizes (<= a few thousand) this is plenty fast.
    """
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad shapes {a.shape} x {b.shape}")
    m, k = a.shape
    _, p = b.shape
    out = np.zeros((m, p), dtype=np.uint8)
    for j in range(k):  # rank-1 updates: table[a[:,j]][:,None] "times" b[j,:]
        col = a[:, j]
        row = b[j, :]
        out ^= GF_MUL_TABLE[col[:, None], row[None, :]]
    return out


def gf_matvec(a: np.ndarray, v: np.ndarray) -> np.ndarray:
    return gf_matmul(a, v.reshape(-1, 1)).ravel()


def gf_rref(a: np.ndarray) -> tuple[np.ndarray, list[int]]:
    """Reduced row-echelon form over GF(256). Returns (R, pivot_columns)."""
    r = np.asarray(a, dtype=np.uint8).copy()
    rows, cols = r.shape
    pivots: list[int] = []
    pr = 0
    for c in range(cols):
        if pr >= rows:
            break
        nz = np.nonzero(r[pr:, c])[0]
        if nz.size == 0:
            continue
        piv = pr + nz[0]
        if piv != pr:
            r[[pr, piv]] = r[[piv, pr]]
        r[pr] = gf_mul(r[pr], GF_INV_TABLE[r[pr, c]])
        mask = np.nonzero(r[:, c])[0]
        mask = mask[mask != pr]
        if mask.size:
            r[mask] ^= GF_MUL_TABLE[r[mask, c][:, None], r[pr][None, :]]
        pivots.append(c)
        pr += 1
    return r, pivots


def gf_rank(a: np.ndarray) -> int:
    if a.size == 0:
        return 0
    return len(gf_rref(a)[1])


def gf_solve(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Solve a @ x = b over GF(256); raises if inconsistent.

    Returns one solution (free variables set to 0).  b may be a matrix.
    """
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    single = b.ndim == 1
    if single:
        b = b.reshape(-1, 1)
    aug = np.concatenate([a, b], axis=1)
    r, pivots = gf_rref(aug)
    n = a.shape[1]
    for c in pivots:
        if c >= n:
            raise np.linalg.LinAlgError("inconsistent GF(256) system")
    x = np.zeros((n, b.shape[1]), dtype=np.uint8)
    for i, c in enumerate(pivots):
        x[c] = r[i, n:]
    return x.ravel() if single else x


def gf_inv_matrix(a: np.ndarray) -> np.ndarray:
    a = np.asarray(a, dtype=np.uint8)
    n = a.shape[0]
    if a.shape != (n, n):
        raise ValueError("square matrix required")
    aug = np.concatenate([a, np.eye(n, dtype=np.uint8)], axis=1)
    r, pivots = gf_rref(aug)
    if pivots != list(range(n)):
        raise np.linalg.LinAlgError("singular GF(256) matrix")
    return r[:, n:]


def gf_nullspace(a: np.ndarray) -> np.ndarray:
    """Basis (rows) of the right nullspace of a over GF(256)."""
    a = np.asarray(a, dtype=np.uint8)
    rows, cols = a.shape
    r, pivots = gf_rref(a)
    free = [c for c in range(cols) if c not in pivots]
    basis = np.zeros((len(free), cols), dtype=np.uint8)
    for bi, fc in enumerate(free):
        basis[bi, fc] = 1
        for i, pc in enumerate(pivots):
            basis[bi, pc] = r[i, fc]  # -r == r in char 2
    return basis


def cauchy_matrix(xs: np.ndarray, ys: np.ndarray) -> np.ndarray:
    """Cauchy matrix C[i,j] = 1/(x_i + y_j); any square submatrix invertible."""
    xs = np.asarray(xs, dtype=np.uint8)
    ys = np.asarray(ys, dtype=np.uint8)
    s = xs[:, None] ^ ys[None, :]
    if np.any(s == 0):
        raise ValueError("x_i + y_j must be nonzero for a Cauchy matrix")
    return GF_INV_TABLE[s]


def rs_generator(n: int, k: int) -> np.ndarray:
    """Systematic (n,k) RS generator over GF(256): [I_k ; P] (n x k).

    Parity part is Cauchy, so every k x k submatrix of G is invertible (MDS).
    Requires n <= 256.
    """
    if not (0 < k < n <= FIELD):
        raise ValueError(f"bad RS parameters n={n} k={k}")
    xs = np.arange(k, n, dtype=np.uint8)  # n-k values
    ys = np.arange(0, k, dtype=np.uint8)
    parity = cauchy_matrix(xs, ys)  # (n-k, k)
    return np.concatenate([np.eye(k, dtype=np.uint8), parity], axis=0)


def gf_mul_bitmatrix(c: int) -> np.ndarray:
    """8x8 GF(2) matrix M with: bits(c * x) = M @ bits(x) (mod 2).

    Column j is bits(c * 2^j).  Bit order: LSB first.
    """
    m = np.zeros((8, 8), dtype=np.uint8)
    for j in range(8):
        prod = int(gf_mul(c, 1 << j))
        for i in range(8):
            m[i, j] = (prod >> i) & 1
    return m


def gf_matrix_to_bitmatrix(a: np.ndarray) -> np.ndarray:
    """Expand (m,k) GF(256) matrix to (8m,8k) GF(2) bit-matrix.

    This is the TPU-native representation: GF(256) matmul == bit-matrix
    matmul over GF(2) on bit-unpacked data (see kernels/gf_matmul.py).
    """
    a = np.asarray(a, dtype=np.uint8)
    m, k = a.shape
    out = np.zeros((8 * m, 8 * k), dtype=np.uint8)
    for i in range(m):
        for j in range(k):
            if a[i, j]:
                out[8 * i : 8 * i + 8, 8 * j : 8 * j + 8] = gf_mul_bitmatrix(int(a[i, j]))
    return out


class GFRandom:
    """Deterministic GF(256) randomness for construction searches."""

    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)

    def nonzero(self, shape=()) -> np.ndarray:
        return self._rng.integers(1, FIELD, size=shape, dtype=np.uint8)

    def any(self, shape=()) -> np.ndarray:
        return self._rng.integers(0, FIELD, size=shape, dtype=np.uint8)
