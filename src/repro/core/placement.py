"""Block placement: flat (r = n) vs hierarchical (r < n) — paper §2.1/§3.1.

A stripe's n blocks live on n distinct nodes spread evenly over r racks
(n/r nodes per rack).  Flat placement (r = n) is the conventional
one-block-per-rack layout; hierarchical placement (r < n) trades rack-level
fault tolerance for minimal cross-rack repair bandwidth.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Placement:
    n: int
    r: int

    def __post_init__(self):
        if self.r < 1 or self.r > self.n or self.n % self.r != 0:
            raise ValueError(f"r={self.r} must divide n={self.n}")

    @property
    def nodes_per_rack(self) -> int:
        return self.n // self.r

    def rack_of(self, node: int) -> int:
        if not 0 <= node < self.n:
            raise ValueError(f"node {node} out of range")
        return node // self.nodes_per_rack

    def nodes_in_rack(self, rack: int) -> list[int]:
        w = self.nodes_per_rack
        return list(range(rack * w, (rack + 1) * w))

    def rack_mates(self, node: int) -> list[int]:
        return [u for u in self.nodes_in_rack(self.rack_of(node)) if u != node]

    def other_racks(self, rack: int) -> list[int]:
        return [t for t in range(self.r) if t != rack]

    @property
    def is_flat(self) -> bool:
        return self.r == self.n

    def rack_failure_tolerance(self, n_minus_k: int) -> int:
        """How many whole-rack failures the stripe survives."""
        return n_minus_k // self.nodes_per_rack
