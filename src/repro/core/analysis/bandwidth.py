"""Numerical analysis of cross-rack repair bandwidth (paper §3.3, Fig. 3).

Unlike the paper's closed-form plots, these numbers are *measured from the
actual repair plans* of the implemented codes (averaged over every failed
node) and then cross-checked against Eq. (1)/(2)/(3); any divergence is a
bug in a construction, which is why the benchmark asserts equality.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..codes import make_code
from ..codes.registry import PAPER_CODES


@dataclass(frozen=True)
class BandwidthRow:
    family: str
    n: int
    k: int
    r: int
    cross_rack_blocks: float  # measured from repair plans
    closed_form: float  # Eq. (1)/(2)/(3) prediction
    total_blocks: float
    storage_overhead: float
    rack_tolerance: int

    @property
    def label(self) -> str:
        return f"{self.family}({self.n},{self.k},{self.r})"


def measure(family: str, n: int, k: int, r: int) -> BandwidthRow:
    code = make_code(family, n, k, r)
    cross = 0.0
    total = 0.0
    for f in range(code.n):
        t = code.repair_plan(f).traffic_blocks()
        cross += t["cross_rack_blocks"]
        total += t["total_blocks"]
    cross /= code.n
    total /= code.n
    return BandwidthRow(
        family=family,
        n=n,
        k=k,
        r=code.r,
        cross_rack_blocks=cross,
        closed_form=code.theoretical_cross_rack_blocks(),
        total_blocks=total,
        storage_overhead=code.storage_overhead,
        rack_tolerance=code.placement.rack_failure_tolerance(n - k),
    )


def fig3_rows() -> list[BandwidthRow]:
    """All Fig. 3 configurations, grouped by n-k like the paper."""
    return [measure(*cfg) for cfg in PAPER_CODES]


def cross_rack_table() -> dict[str, float]:
    return {row.label: row.cross_rack_blocks for row in fig3_rows()}


def paper_observations() -> dict[str, float]:
    """The §3.3 bullet-point claims, computed from measured rows."""
    t = cross_rack_table()
    return {
        # RS(8,6,8) has 50% higher cross-rack bw than RS(6,4,6)
        "rs86_vs_rs64_pct": 100.0 * (t["RS(8,6,8)"] / t["RS(6,4,6)"] - 1.0),
        # RS(6,4,3) is 25% below RS(6,4,6); MSR(6,4,3) 20% below MSR(6,4,6)
        "rs643_saving_pct": 100.0 * (1.0 - t["RS(6,4,3)"] / t["RS(6,4,6)"]),
        "msr643_saving_pct": 100.0 * (1.0 - t["MSR(6,4,3)"] / t["MSR(6,4,6)"]),
        # DRC(9,5,3) incurs 66.7% less cross-rack bw than RS(9,5,3)
        "drc953_vs_rs953_pct": 100.0 * (1.0 - t["DRC(9,5,3)"] / t["RS(9,5,3)"]),
        # DRC(9,5,3) incurs 33.3% less than MSR(8,4,4)
        "drc953_vs_msr844_pct": 100.0 * (1.0 - t["DRC(9,5,3)"] / t["MSR(8,4,4)"]),
    }
