"""Markov MTTDL reliability analysis (paper §3.4, Tables 1 and 2).

States count healthy nodes: n (all healthy) down to k-1 (data loss,
absorbing).  Independent node failures move i -> i-1 at rate i·λ1.
Correlated (rack power-outage) failures act only from the all-healthy
state: with w = n/r nodes per rack, a j-node correlated failure in one of
r racks has rate r·C(w,j)·λ2^j (the paper's 9λ2 / 9λ2² / 3λ2³ cases for
(9,6,3)).  Repair of a single failure runs at μ = γ/(C·S) with C the
repair bandwidth per unit of repaired data (C = 8/3 for MSR(9,6) flat,
C = 2 for DRC(9,6,3)); deeper states repair one node at a time at
μ' = γ/(k·S).

MTTDL is the expected absorption time from state n, solved exactly from
the embedded linear system (no simulation).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

SECONDS_PER_YEAR = 365.25 * 24 * 3600


@dataclass
class MTTDLModel:
    n: int = 9
    k: int = 6
    r: int = 9  # racks; r == n -> flat placement
    mttf_years: float = 4.0  # 1/λ1
    lambda2: float = 0.0  # correlated per-node failure rate (per year)
    gamma_gbps: float = 1.0  # available cross-rack bandwidth
    node_capacity_tib: float = 1.0  # S
    c_single: float = 8.0 / 3.0  # repair bw per unit data, single failure
    c_multi: float | None = None  # defaults to k (MDS whole-stripe repair)

    def _mu(self, c: float) -> float:
        """Repair rate (per year) for repair cost c·S at γ Gb/s."""
        bits = c * self.node_capacity_tib * (2**40) * 8
        seconds = bits / (self.gamma_gbps * 1e9)
        return SECONDS_PER_YEAR / seconds

    def mttdl_years(self) -> float:
        n, k = self.n, self.k
        lam1 = 1.0 / self.mttf_years
        lam2 = self.lambda2
        w = n // self.r
        mu_single = self._mu(self.c_single)
        mu_multi = self._mu(self.c_multi if self.c_multi is not None else self.k)

        states = list(range(n, k - 1, -1))  # transient: n .. k
        idx = {s: i for i, s in enumerate(states)}
        m = len(states)
        # Q[i][j]: rate from state i to state j (transient only);
        # absorption rate folds into the diagonal.
        q = np.zeros((m, m))
        out = np.zeros(m)
        for s in states:
            i = idx[s]
            # independent failures
            rate = s * lam1
            out[i] += rate
            if s - 1 >= k:
                q[i, idx[s - 1]] += rate
            # correlated failures from the all-healthy state only
            if s == n and lam2 > 0:
                for j in range(1, w + 1):
                    rate = self.r * math.comb(w, j) * (lam2**j)
                    out[i] += rate
                    if s - j >= k:
                        q[i, idx[s - j]] += rate
            # repairs
            if s < n:
                mu = mu_single if s == n - 1 else mu_multi
                out[i] += mu
                q[i, idx[s + 1]] += mu
        # T_i = 1/out_i + sum_j (q_ij/out_i) T_j  ->  (I - P) T = 1/out
        p = q / out[:, None]
        t = np.linalg.solve(np.eye(m) - p, 1.0 / out)
        return float(t[idx[n]])


def _model(flat: bool, correlated: bool, mttf: float, gamma: float) -> MTTDLModel:
    if flat:
        return MTTDLModel(
            r=9,
            c_single=8.0 / 3.0,  # MSR(9,6) flat, Eq. (2)
            mttf_years=mttf,
            lambda2=0.005 if correlated else 0.0,
            gamma_gbps=gamma,
        )
    return MTTDLModel(
        r=3,
        c_single=2.0,  # DRC(9,6,3), Eq. (3)
        mttf_years=mttf,
        lambda2=0.005 if correlated else 0.0,
        gamma_gbps=gamma,
    )


def table1_rows(gamma_gbps: float = 1.0) -> dict[str, list[float]]:
    """Paper Table 1: vary 1/λ1 in years at γ = 1 Gb/s."""
    mttfs = [2, 4, 6, 8, 10]
    return {
        "mttf_years": mttfs,
        "flat_no_corr": [_model(True, False, m, gamma_gbps).mttdl_years() for m in mttfs],
        "flat_corr": [_model(True, True, m, gamma_gbps).mttdl_years() for m in mttfs],
        "hier_no_corr": [_model(False, False, m, gamma_gbps).mttdl_years() for m in mttfs],
        "hier_corr": [_model(False, True, m, gamma_gbps).mttdl_years() for m in mttfs],
    }


def table2_rows(mttf_years: float = 4.0) -> dict[str, list[float]]:
    """Paper Table 2: vary γ in Gb/s at 1/λ1 = 4 years."""
    gammas = [0.2, 0.5, 1.0, 2.0]
    return {
        "gamma_gbps": gammas,
        "flat_no_corr": [_model(True, False, mttf_years, g).mttdl_years() for g in gammas],
        "flat_corr": [_model(True, True, mttf_years, g).mttdl_years() for g in gammas],
        "hier_no_corr": [_model(False, False, mttf_years, g).mttdl_years() for g in gammas],
        "hier_corr": [_model(False, True, mttf_years, g).mttdl_years() for g in gammas],
    }


# The paper's published values, used as regression targets (±15%: the
# paper does not state its exact TiB/year unit conventions).
PAPER_TABLE1 = {
    "flat_no_corr": [2.56e6, 4.08e7, 2.06e8, 6.52e8, 1.59e9],
    "flat_corr": [2.54e6, 4.00e7, 2.00e8, 6.27e8, 1.51e9],
    "hier_no_corr": [3.41e6, 5.44e7, 2.75e8, 8.69e8, 2.12e9],
    "hier_corr": [3.28e6, 4.69e7, 1.96e8, 4.81e8, 8.80e8],
}
PAPER_TABLE2 = {
    "flat_no_corr": [3.32e5, 5.12e6, 4.08e7, 3.26e8],
    "flat_corr": [3.26e5, 5.02e6, 4.00e7, 3.19e8],
    "hier_no_corr": [4.42e5, 6.82e6, 5.44e7, 4.34e8],
    "hier_corr": [4.25e5, 6.33e6, 4.69e7, 3.09e8],
}
