from .bandwidth import cross_rack_table, fig3_rows
from .reliability import MTTDLModel, table1_rows, table2_rows

__all__ = [
    "cross_rack_table",
    "fig3_rows",
    "MTTDLModel",
    "table1_rows",
    "table2_rows",
]
