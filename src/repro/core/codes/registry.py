"""Code registry: build any code evaluated in the paper by family string.

`PAPER_CODES` is the full set of configurations appearing in §3.3 Fig. 3 and
§6 (Figs. 6–8): RS / MSR baselines and the five deployed DRC configs.
"""
from __future__ import annotations

from ..code_base import ErasureCode
from .drc_family1 import DRCFamily1
from .drc_family2 import DRCFamily2
from .msr_clay import MSRCode
from .rs_code import RSCode

_FAMILIES = {
    "RS": RSCode,
    "MSR": MSRCode,
}


def make_code(family: str, n: int, k: int, r: int | None = None) -> ErasureCode:
    family = family.upper()
    if family == "DRC":
        m = n - k
        if n % 3 == 0 and k == 2 * (n // 3) - 1 and (r in (None, 3)):
            return DRCFamily2(n, k, 3)
        if m >= 2 and n % m == 0 and (r in (None, n // m)):
            return DRCFamily1(n, k, r)
        raise ValueError(f"no DRC family matches ({n},{k},{r})")
    if family not in _FAMILIES:
        raise ValueError(f"unknown code family {family!r}")
    return _FAMILIES[family](n, k, r)


# Every configuration the paper evaluates (Fig. 3 groups by n-k; §6 testbed).
PAPER_CODES: list[tuple[str, int, int, int]] = [
    # --- n-k = 2 group (Fig. 3a) ---
    ("RS", 6, 4, 6),
    ("RS", 6, 4, 3),
    ("RS", 8, 6, 8),
    ("RS", 8, 6, 4),
    ("MSR", 6, 4, 6),
    ("MSR", 6, 4, 3),
    ("MSR", 8, 6, 8),
    ("MSR", 8, 6, 4),
    ("DRC", 6, 4, 3),
    ("DRC", 8, 6, 4),
    # --- n-k = 3 group (Fig. 3b) ---
    ("RS", 6, 3, 6),
    ("RS", 6, 3, 3),
    ("RS", 9, 6, 9),
    ("RS", 9, 6, 3),
    ("MSR", 6, 3, 6),
    ("MSR", 6, 3, 3),
    ("DRC", 6, 3, 3),
    ("DRC", 9, 6, 3),
    # --- n-k = 4 group (Fig. 3c) ---
    ("RS", 8, 4, 8),
    ("RS", 8, 4, 4),
    ("RS", 9, 5, 9),
    ("RS", 9, 5, 3),
    ("MSR", 8, 4, 8),
    ("MSR", 8, 4, 4),
    ("DRC", 8, 4, 2),
    ("DRC", 9, 5, 3),
]

# The five DRC configs implemented in the paper's DoubleR prototype (§4.1).
PROTOTYPE_DRC: list[tuple[int, int, int]] = [
    (6, 4, 3),  # Family 1
    (8, 6, 4),  # Family 1
    (9, 6, 3),  # Family 1
    (6, 3, 3),  # Family 2
    (9, 5, 3),  # Family 2
]
