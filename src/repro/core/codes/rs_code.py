"""Reed-Solomon baseline (paper §3.1 Eq. (1), §3.3).

α = 1.  Repair of one block retrieves k available blocks; under hierarchical
placement the target takes all n/r - 1 local blocks first and the remaining
k - (n/r - 1) from non-local racks (the paper's best-case RS accounting).
"""
from __future__ import annotations

import numpy as np

from .. import gf
from ..code_base import ErasureCode, rs_repair_blocks
from ..repair import TARGET, RepairPlan, Send, build_target_order


class RSCode(ErasureCode):
    name = "RS"

    def __init__(self, n: int, k: int, r: int | None = None):
        super().__init__(n, k, r if r is not None else n, alpha=1)

    def _build_generator(self) -> np.ndarray:
        return gf.rs_generator(self.n, self.k)

    def repair_plan(self, failed: int, rotation: int = 0) -> RepairPlan:
        pl = self.placement
        local = [u for u in pl.rack_mates(failed)]
        helpers = list(local[: self.k])
        if len(helpers) < self.k:
            # fill from non-local racks, round-robin for balance
            racks = pl.other_racks(pl.rack_of(failed))
            pools = [list(pl.nodes_in_rack(t)) for t in racks]
            i = 0
            while len(helpers) < self.k:
                if pools[i % len(pools)]:
                    helpers.append(pools[i % len(pools)].pop(0))
                i += 1
        helpers = sorted(helpers)
        rows = np.concatenate([self.node_coeffs(u) for u in helpers], axis=0)
        # decode: d @ rows = G_failed
        d = gf.gf_solve(rows.T, self.node_coeffs(failed).T).T
        node_sends = [
            Send(src=u, dst=TARGET, matrix=np.eye(1, dtype=np.uint8)) for u in helpers
        ]
        plan = RepairPlan(
            failed=failed,
            placement=pl,
            alpha=1,
            node_sends=node_sends,
            relayer_sends=[],
            decode=np.ascontiguousarray(d),
            target_order=build_target_order(node_sends, []),
        )
        return plan

    def theoretical_cross_rack_blocks(self) -> float:
        return rs_repair_blocks(self.k) - (self.placement.nodes_per_rack - 1)

    def theoretical_total_blocks(self) -> float:
        return rs_repair_blocks(self.k)
