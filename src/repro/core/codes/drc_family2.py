"""DRC Family 2: DRC(3z, 2z-1, 3) — paper §4.3 (repair-by-transfer).

Construction: each block is split into α = 2 subblocks; the subblocks at the
same offset across the k = 2z-1 data blocks form a *set*; each set is
independently encoded with a systematic (3z, 2z-1) RS code into z+1 parity
subblocks.  Node i stores (set-0 symbol i, set-1 symbol i).  n = 3z blocks
are placed across 3 racks of z nodes.

Repair of node f (rack R_i): assign set 0 to one non-local rack R_j and set 1
to the other, R_l.  For set s and helper rack R_h there is (generically) a
unique dual codeword h of the per-set RS code supported on R_i ∪ R_h with
h_f ≠ 0.  Non-relayer nodes of R_h forward their raw set-s subblock to the
relayer (repair-by-transfer: pure disk read, no arithmetic — paper Goal /
§4.3); the relayer combines them with weights h|R_h and ships ONE unit
cross-rack.  The target cancels the local part h|R_i using its rack-mates'
raw subblocks and solves for the failed symbol.  Cross-rack traffic:
2 × B/2 = B = Eq. (3) minimum; each relayer ships exactly one unit (Goal 8).
"""
from __future__ import annotations

import numpy as np

from .. import gf
from ..code_base import drc_min_cross_rack_blocks
from ..repair import TARGET, RepairPlan, Send, build_target_order
from .stripwise import StripwiseRS


class DRCFamily2(StripwiseRS):
    name = "DRC"

    def __init__(self, n: int, k: int, r: int = 3):
        if r != 3 or n % 3 or k != 2 * (n // 3) - 1:
            raise ValueError(
                f"Family 2 requires (n,k,r)=(3z,2z-1,3); got ({n},{k},{r})"
            )
        self.z = n // 3
        super().__init__(n, k, r, alpha=2)

    # ------------------------------------------------------------------
    def _dual_two_racks(self, s_set: int, rack_i: int, rack_h: int, failed: int):
        """Dual codeword of the per-set code supported on racks i∪h, h_f != 0."""
        pl = self.placement
        dual = gf.gf_nullspace(self.set_gens[s_set].T)  # rows h: h @ G_t = 0
        outside = [
            u
            for u in range(self.n)
            if pl.rack_of(u) not in (rack_i, rack_h)
        ]
        combo_ns = gf.gf_nullspace(dual[:, outside].T)  # combos vanishing outside
        if combo_ns.shape[0] == 0:
            return None
        for c in combo_ns:
            h = gf.gf_matmul(c.reshape(1, -1), dual).ravel()
            if h[failed] != 0:
                return h
        # try random combos in the surviving space
        rng = gf.GFRandom(seed=failed * 131 + s_set)
        for _ in range(64):
            c = rng.any((1, combo_ns.shape[0]))
            h = gf.gf_matmul(gf.gf_matmul(c, combo_ns), dual).ravel()
            if h[failed] != 0 and not h[outside].any():
                return h
        return None

    def repair_plan(self, failed: int, rotation: int = 0) -> RepairPlan:
        pl = self.placement
        rack_f = pl.rack_of(failed)
        helper_racks = pl.other_racks(rack_f)
        # balanced assignment: set s -> helper rack (rotated by failed rack for
        # cluster-level balance when repairing many stripes)
        assignments = [
            (0, helper_racks[0], 1, helper_racks[1]),
            (0, helper_racks[1], 1, helper_racks[0]),
        ]
        last_err = None
        for a0_set, a0_rack, a1_set, a1_rack in assignments:
            try:
                return self._plan_with_assignment(
                    failed, {a0_set: a0_rack, a1_set: a1_rack}, rotation
                )
            except ValueError as e:  # degenerate dual; try the swap
                last_err = e
        raise ValueError(f"no feasible Family-2 plan for node {failed}: {last_err}")

    def _plan_with_assignment(
        self, failed: int, set_to_rack: dict[int, int], rotation: int = 0
    ) -> RepairPlan:
        pl = self.placement
        rack_f = pl.rack_of(failed)
        duals = {}
        for s_set, rack_h in set_to_rack.items():
            h = self._dual_two_racks(s_set, rack_f, rack_h, failed)
            if h is None:
                raise ValueError(f"no dual codeword for set {s_set} rack {rack_h}")
            duals[s_set] = h

        node_sends: list[Send] = []
        relayer_sends: list[Send] = []

        # local rack-mates ship both raw subblocks (inner-rack)
        locals_ = pl.rack_mates(failed)
        for u in locals_:
            node_sends.append(Send(u, TARGET, np.eye(2, dtype=np.uint8)))

        # helper racks: repair-by-transfer into the relayer, combine, ship one
        relayer_units: dict[int, np.ndarray] = {}
        for s_set, rack_h in sorted(set_to_rack.items()):
            h = duals[s_set]
            nodes = pl.nodes_in_rack(rack_h)
            relayer = nodes[(failed + rotation) % len(nodes)]  # per-stripe rotation
            mates = [u for u in nodes if u != relayer]
            sel = np.zeros((1, 2), dtype=np.uint8)
            sel[0, s_set] = 1  # raw set-s subblock, no arithmetic
            for u in mates:
                node_sends.append(Send(u, relayer, sel.copy()))
            # relayer input = [own 2 subblocks] ++ [mates' raw units in src order]
            in_dim = 2 + len(mates)
            m = np.zeros((1, in_dim), dtype=np.uint8)
            m[0, s_set] = h[relayer]
            for pos, u in enumerate(sorted(mates)):
                m[0, 2 + pos] = h[u]
            relayer_sends.append(Send(relayer, TARGET, m))
            relayer_units[s_set] = h

        # ---------------- decode at target ----------------
        # target input order: local raw units (src asc) then relayer units
        # (src asc).  Build coefficient rows and solve for G_failed.
        coeffs = self.all_node_coeffs()
        rows = []
        for u in sorted(locals_):
            rows.append(coeffs[u])
        for s in sorted(relayer_sends, key=lambda x: x.src):
            inputs = [coeffs[s.src]]
            for ns in sorted(
                (x for x in node_sends if x.dst == s.src), key=lambda x: x.src
            ):
                inputs.append(gf.gf_matmul(ns.matrix, coeffs[ns.src]))
            rows.append(gf.gf_matmul(s.matrix, np.concatenate(inputs, axis=0)))
        stacked = np.concatenate(rows, axis=0)
        decode = gf.gf_solve(stacked.T, coeffs[failed].T).T
        return RepairPlan(
            failed=failed,
            placement=pl,
            alpha=2,
            node_sends=node_sends,
            relayer_sends=relayer_sends,
            decode=np.ascontiguousarray(decode),
            target_order=build_target_order(node_sends, relayer_sends),
        )

    def theoretical_cross_rack_blocks(self) -> float:
        return drc_min_cross_rack_blocks(self.n, self.k, self.r)
