"""DRC Family 1: DRC(n, k, n/(n-k)) — paper §4.2 (interference alignment).

Construction (paper): split each block into m = n-k subblocks; the
subblocks at the same offset across the k data blocks form a *set*
(m sets); each set is independently encoded with a systematic Cauchy-RS
(n, k) code.  Node i stores the i-th symbol of every set.  n blocks are
placed across r = n/m racks of m nodes each (k = (r-1)·m data nodes fill
r-1 racks; the parity nodes fill the last rack).

Repair (generic interference-alignment solver).  For failed node f:

* rack-mates of f ship their full blocks (inner-rack);
* in every non-local rack, each non-relayer node ships `budget` encoded
  subblock(s) c_u·(its m subblocks) to the rack's relayer (inner-rack);
* each relayer ships exactly m re-encoded subblocks cross-rack (Goal 8),
  so the cross-rack traffic is (r-1)·m·(B/m) = (r-1)·B — Eq. (3)'s minimum
  for r = n/(n-k).

The alignment condition is that G_f's rows lie in the span of
[locals ∪ relayer-own rows ∪ {c_u G_u}].  We solve for the c_u directions
with the dual method: let Q span the nullspace of the fixed rows; the
residual nullspace after adding the tunable rows must sit inside
Null(G_f·). We pick a random v*-dimensional subspace V* of that null space
(v* = dim Null(fixed) - #tunables) and constrain every c_u to annihilate
V*'s image W_u = G_u Qᵀ — a *linear* condition on c_u.  Randomize-and-
verify handles degeneracies; `budget` auto-increases for parameter sets
where one subblock per non-relayer cannot absorb the alignment constraints
(all of the paper's deployed configs — (6,4,3), (8,6,4), (9,6,3) — work at
budget 1, which is what Goal 7 'relayer-in ≤ relayer-out' requires).

For the paper's per-node walk-through of (9,6,3) see §4.2; this module
reproduces those bandwidth numbers exactly (tests/test_codes.py).
"""
from __future__ import annotations

import functools

import numpy as np

from .. import gf
from ..code_base import drc_min_cross_rack_blocks
from ..repair import TARGET, RepairPlan, Send, build_target_order
from .stripwise import StripwiseRS


class DRCFamily1(StripwiseRS):
    name = "DRC"

    def __init__(self, n: int, k: int, r: int | None = None):
        m = n - k
        if n % m:
            raise ValueError(f"Family 1 needs (n-k) | n; got ({n},{k})")
        want_r = n // m
        if r is not None and r != want_r:
            raise ValueError(f"Family 1 fixes r = n/(n-k) = {want_r}; got {r}")
        if m < 2:
            raise ValueError("n-k must be >= 2 (use RS otherwise)")
        super().__init__(n, k, want_r, alpha=m)

    # ------------------------------------------------------------------
    @functools.lru_cache(maxsize=64)
    def repair_plan(self, failed: int, rotation: int = 0) -> RepairPlan:  # type: ignore[override]
        """Find the lowest-inner-traffic feasible alignment.

        Non-relayer budgets (encoded subblocks shipped to the rack relayer)
        start at 1 each and are escalated one unit at a time, round-robin
        across racks, up to the full block.  Goal 7 (relayer-in ≤
        relayer-out = m units) holds as long as the per-rack total stays
        ≤ m; the paper's deployed configs (6,4,3)/(9,6,3) resolve at 1 per
        node and (8,6,4) at one full block — all within the Goal-7 cap.
        """
        pl, m = self.placement, self.alpha
        # Data-node repair: the paper's structured interference alignment
        # (§4.2) — budget exactly 1 per non-relayer, Goal 7 tight.
        if failed < self.k:
            for attempt in range(8):
                plan = self._structured_data_plan(failed, seed=attempt, rotation=rotation)
                if plan is not None:
                    return plan
        # Parity nodes (and any degenerate draw): generic escalation solver.
        racks = pl.other_racks(pl.rack_of(failed))
        max_extra = len(racks) * (m - 1) * (m - 1)
        for extra in range(max_extra + 1):
            for attempt in range(6):
                plan = self._try_plan(
                    failed, extra, seed=attempt * 977 + failed * 13 + extra,
                    rotation=rotation,
                )
                if plan is not None:
                    return plan
        raise ValueError(f"no feasible Family-1 alignment for node {failed}")

    # -------------------------------------------------- structured (paper)
    def _coord(self, node: int, t: int) -> int:
        """Data-coordinate index of data node `node`, set t."""
        return node * self.alpha + t

    def _structured_data_plan(
        self, failed: int, seed: int, rotation: int = 0
    ) -> RepairPlan | None:
        """Paper §4.2 alignment, generalized.

        e_1 is a combination of the parity relayer's own subblocks; every
        further unit e_q adds exactly one parity mate's single combination,
        tuned (inhomogeneous square solve) so that e_q's projection onto
        every *far* data node equals e_1's.  Far mates then ship that very
        projection as their single combo; data-rack relayers reproduce
        proj_{rack}(e_j) from [own block ++ mate combos]; the target strips
        locals and per-rack units from e_j, leaving an m×m system on the
        failed node's subblocks.
        """
        pl, m = self.placement, self.alpha
        rng = gf.GFRandom(seed * 7919 + failed)
        coeffs = self.all_node_coeffs()
        rack_f = pl.rack_of(failed)
        parity_rack = pl.r - 1
        if rack_f == parity_rack:
            return None
        locals_ = sorted(pl.rack_mates(failed))
        data_racks = [
            t for t in pl.other_racks(rack_f) if t != parity_rack
        ]
        w = pl.nodes_in_rack(parity_rack)[(failed + rotation) % m]  # parity relayer
        w_mates = sorted(u for u in pl.nodes_in_rack(parity_rack) if u != w)
        relayer_of = {
            t: pl.nodes_in_rack(t)[(failed + rotation) % m] for t in data_racks
        }
        far = sorted(
            u
            for t in data_racks
            for u in pl.nodes_in_rack(t)
            if u != relayer_of[t]
        )
        far_coords = [self._coord(u, t) for u in far for t in range(m)]

        g_w = coeffs[w]  # (m, D)
        sigma = rng.nonzero((1, m))
        e = [gf.gf_matmul(sigma, g_w).ravel()]  # e_1
        # far-mate combos: c_u = proj_u(e_1); every later e_q must align to
        # a *scalar multiple* of c_u on node u's coordinates (the relayer
        # rescales each received combo independently per sent unit).
        c_far = {
            u: e[0][[self._coord(u, t) for t in range(m)]].reshape(1, -1)
            for u in far
        }
        if any(not c_far[u].any() for u in far):
            return None
        lambdas: dict[tuple[int, int], int] = {(0, u): 1 for u in far}

        d_combos: dict[int, np.ndarray] = {}
        for qi, wq in enumerate(w_mates):
            # homogeneous system in (gamma, d, lambda_u):
            #   proj_far(gamma·G_w + d·G_wq) - sum_u lambda_u·c_u|_u = 0
            nfar = len(far)
            a = np.zeros((len(far_coords), 2 * m + nfar), dtype=np.uint8)
            a[:, :m] = g_w[:, far_coords].T
            a[:, m : 2 * m] = coeffs[wq][:, far_coords].T
            for ui, u in enumerate(far):
                for t in range(m):
                    a[ui * m + t, 2 * m + ui] = c_far[u][0, t]
            kernel = gf.gf_nullspace(a)  # rows = solutions
            # pick a kernel element with d != 0
            cand = [v for v in kernel if v[m : 2 * m].any()]
            if not cand:
                return None
            mix = rng.any((1, len(cand)))
            sol = gf.gf_matmul(mix, np.stack(cand, axis=0)).ravel()
            if not sol[m : 2 * m].any():
                sol = cand[0]
            gamma, d, lam = sol[:m], sol[m : 2 * m], sol[2 * m :]
            e_q = gf.gf_matmul(gamma.reshape(1, -1), g_w) ^ gf.gf_matmul(
                d.reshape(1, -1), coeffs[wq]
            )
            e.append(e_q.ravel())
            d_combos[wq] = d.reshape(1, -1)
            for ui, u in enumerate(far):
                lambdas[(qi + 1, u)] = int(lam[ui])
        e_mat = np.stack(e, axis=0)  # (m, D)

        # failed-node projection matrix must be invertible
        m_proj = e_mat[:, [self._coord(failed, t) for t in range(m)]]
        if gf.gf_rank(m_proj) < m:
            return None
        m_inv = gf.gf_inv_matrix(m_proj)

        node_sends: list[Send] = []
        for u in locals_:
            node_sends.append(Send(u, TARGET, np.eye(m, dtype=np.uint8)))
        for t in data_racks:
            for u in pl.nodes_in_rack(t):
                if u != relayer_of[t]:
                    node_sends.append(Send(u, relayer_of[t], c_far[u].copy()))
        for wq in w_mates:
            node_sends.append(Send(wq, w, d_combos[wq].copy()))

        relayer_sends: list[Send] = []
        # data-rack relayers: s^b_j = proj_{R_b}(e_j)
        for t in data_racks:
            v = relayer_of[t]
            mates = sorted(u for u in pl.nodes_in_rack(t) if u != v)
            rmat = np.zeros((m, m + len(mates)), dtype=np.uint8)
            for j in range(m):
                rmat[j, :m] = e_mat[j, [self._coord(v, tt) for tt in range(m)]]
                for mi, u in enumerate(mates):
                    rmat[j, m + mi] = lambdas[(j, u)]
            relayer_sends.append(Send(v, TARGET, rmat))
        # parity relayer: express e_j over [own rows ++ received mate units]
        pmat = self._parity_relayer_matrix(e_mat, coeffs, w, w_mates, d_combos)
        if pmat is None:
            return None
        relayer_sends.append(Send(w, TARGET, pmat))

        # ---- decode ----
        # target units: locals raw (m each, src asc), then relayer units
        # (src asc; data relayers and the parity relayer interleaved by id).
        unit_srcs: list[tuple[int, int]] = []  # (src, row)
        for u in sorted(locals_):
            unit_srcs += [(u, j) for j in range(m)]
        for s in sorted(relayer_sends, key=lambda x: x.src):
            unit_srcs += [(s.src, j) for j in range(m)]
        n_units = len(unit_srcs)
        c = np.zeros((m, n_units), dtype=np.uint8)
        for j in range(m):
            for pos, (src, row) in enumerate(unit_srcs):
                if src == w and row == j:
                    c[j, pos] = 1
                elif src in relayer_of.values() and row == j:
                    c[j, pos] = 1  # subtract s^b_j (char 2)
                elif src in locals_:
                    c[j, pos] = e_mat[j, self._coord(src, row)]
        decode = gf.gf_matmul(m_inv, c)

        plan = RepairPlan(
            failed=failed,
            placement=pl,
            alpha=m,
            node_sends=node_sends,
            relayer_sends=relayer_sends,
            decode=decode,
            target_order=build_target_order(node_sends, relayer_sends),
        )
        if not plan.coefficient_check(coeffs):
            return None
        return plan

    def _parity_relayer_matrix(self, e_mat, coeffs, w, w_mates, d_combos):
        """Express e_j over [w's own rows ++ received mate units]."""
        basis = [coeffs[w]]
        for wq in sorted(w_mates):
            basis.append(gf.gf_matmul(d_combos[wq], coeffs[wq]))
        stack = np.concatenate(basis, axis=0)
        try:
            x = gf.gf_solve(stack.T, e_mat.T)
        except np.linalg.LinAlgError:
            return None
        return np.ascontiguousarray(x.T)

    def _budgets(self, failed: int, extra: int, rotation: int = 0) -> dict[int, int] | None:
        """Per-non-relayer unit budgets: all 1 plus `extra` units assigned
        round-robin across racks (capped at a full block of m units)."""
        pl, m = self.placement, self.alpha
        racks = pl.other_racks(pl.rack_of(failed))
        relayers = {
            t: pl.nodes_in_rack(t)[(failed + rotation) % m] for t in racks
        }
        order = [
            u
            for _ in range(m - 1)
            for t in racks
            for u in pl.nodes_in_rack(t)
            if u != relayers[t]
        ]
        budgets = {u: 1 for t in racks for u in pl.nodes_in_rack(t) if u != relayers[t]}
        for i in range(extra):
            if i >= len(order):
                return None
            budgets[order[i]] += 1
            if budgets[order[i]] > m:
                return None
        return budgets

    def _try_plan(
        self, failed: int, extra: int, seed: int, rotation: int = 0
    ) -> RepairPlan | None:
        pl, m = self.placement, self.alpha
        rng = gf.GFRandom(seed)
        rack_f = pl.rack_of(failed)
        coeffs = self.all_node_coeffs()
        g_f = coeffs[failed]

        budgets = self._budgets(failed, extra, rotation)
        if budgets is None:
            return None
        locals_ = sorted(pl.rack_mates(failed))
        racks = pl.other_racks(rack_f)
        relayers = {
            t: pl.nodes_in_rack(t)[(failed + rotation) % m] for t in racks
        }
        nonrelayers = {
            t: [u for u in pl.nodes_in_rack(t) if u != relayers[t]] for t in racks
        }

        fixed_rows = [coeffs[u] for u in locals_] + [coeffs[relayers[t]] for t in racks]
        fixed = np.concatenate(fixed_rows, axis=0)
        q_basis = gf.gf_nullspace(fixed)  # (q, D)
        q = q_basis.shape[0]
        tunable_nodes = [u for t in racks for u in nonrelayers[t]]
        n_tun = sum(budgets[u] for u in tunable_nodes)
        vstar_dim = max(q - n_tun, 0)

        c_vecs: dict[int, np.ndarray] = {}
        if vstar_dim == 0:
            for u in tunable_nodes:
                c_vecs[u] = rng.nonzero((budgets[u], m))
        else:
            if any(vstar_dim > m - budgets[u] for u in tunable_nodes):
                return None  # cannot absorb alignment at these budgets
            # V* = random subspace of Null(F) where F = G_f @ Q^T
            f_mat = gf.gf_matmul(g_f, q_basis.T)  # (m, q)
            null_f = gf.gf_nullspace(f_mat)  # (q - p, q) rows: beta with F beta = 0
            if null_f.shape[0] < vstar_dim:
                return None
            mix = rng.any((vstar_dim, null_f.shape[0]))
            b_star = gf.gf_matmul(mix, null_f)  # (v*, q)
            if gf.gf_rank(b_star) < vstar_dim:
                return None
            for u in tunable_nodes:
                bu = budgets[u]
                w_u = gf.gf_matmul(coeffs[u], q_basis.T)  # (m, q)
                cond = gf.gf_matmul(w_u, b_star.T)  # (m, v*): need c_u @ cond = 0
                space = gf.gf_nullspace(cond.T)  # rows: valid c_u
                if space.shape[0] < bu:
                    return None
                mixu = rng.any((bu, space.shape[0]))
                cu = gf.gf_matmul(mixu, space)
                if gf.gf_rank(cu) < bu:
                    cu = space[:bu]
                c_vecs[u] = cu

        tun_rows = [gf.gf_matmul(c_vecs[u], coeffs[u]) for u in tunable_nodes]
        all_rows = np.concatenate([fixed] + tun_rows, axis=0) if tun_rows else fixed
        # feasibility: G_f in span(all rows)
        try:
            x = gf.gf_solve(all_rows.T, g_f.T)  # (rows, m): all^T x = G_f^T
        except np.linalg.LinAlgError:
            return None
        xt = x.T  # (m, rows): G_f = xt @ all_rows

        # ---- assemble plan ----
        node_sends: list[Send] = []
        for u in locals_:
            node_sends.append(Send(u, TARGET, np.eye(m, dtype=np.uint8)))
        for t in racks:
            for u in nonrelayers[t]:
                node_sends.append(Send(u, relayers[t], c_vecs[u].copy()))

        # column ranges of all_rows per provenance
        col = 0
        col_of: dict[tuple[str, int], tuple[int, int]] = {}
        for u in locals_:
            col_of[("local", u)] = (col, col + m)
            col += m
        for t in racks:
            col_of[("rel", relayers[t])] = (col, col + m)
            col += m
        for u in tunable_nodes:
            col_of[("tun", u)] = (col, col + budgets[u])
            col += budgets[u]

        relayer_sends: list[Send] = []
        for t in racks:
            v = relayers[t]
            mates = sorted(nonrelayers[t])
            in_dim = m + sum(budgets[u] for u in mates)
            rmat = np.zeros((m, in_dim), dtype=np.uint8)
            lo, hi = col_of[("rel", v)]
            rmat[:, :m] = xt[:, lo:hi]
            off = m
            for u in mates:
                lo, hi = col_of[("tun", u)]
                rmat[:, off : off + budgets[u]] = xt[:, lo:hi]
                off += budgets[u]
            relayer_sends.append(Send(v, TARGET, rmat))

        # decode: local raw units use xt coefficients; relayer units are the
        # pre-aggregated per-rack contributions -> identity coefficients.
        n_target_units = m * len(locals_) + m * len(racks)
        decode = np.zeros((m, n_target_units), dtype=np.uint8)
        pos = 0
        for u in sorted(locals_):
            lo, hi = col_of[("local", u)]
            decode[:, pos : pos + m] = xt[:, lo:hi]
            pos += m
        for v in sorted(relayers[t] for t in racks):
            decode[:, pos : pos + m] = np.eye(m, dtype=np.uint8)
            pos += m

        plan = RepairPlan(
            failed=failed,
            placement=pl,
            alpha=m,
            node_sends=node_sends,
            relayer_sends=relayer_sends,
            decode=decode,
            target_order=build_target_order(node_sends, relayer_sends),
        )
        if not plan.coefficient_check(coeffs):
            return None
        return plan

    def theoretical_cross_rack_blocks(self) -> float:
        return drc_min_cross_rack_blocks(self.n, self.k, self.r)
