"""MSR codes with d = n-1 via coupled-layer (Ye-Barg / Clay) construction.

The paper's prototype uses Butterfly codes (n-k = 2) and MISER codes
(n = 2k) as its MSR baselines; both sit at the same operating point —
systematic MDS, d = n-1 helpers, repair bandwidth B(n-1)/(n-k) (Eq. (2)).
We implement that operating point once, for *any* (n, k) with (n-k) | n,
using the coupled-layer construction:

* s = n-k, m = n/s; nodes are a grid (x, y) ∈ [s]×[m], node id = y·s + x.
* Subpacketization α = s^m; symbol planes z ∈ [s]^m.
* Stored (coupled) symbols C(x,y; z).  Uncoupled symbols:
      U(x,y;z) = C(x,y;z)                      if z_y = x
      U(x,y;z) = C(x,y;z) + γ·C(z_y,y; z(y→x)) otherwise,
  a pairwise invertible transform for γ ∉ {0,1} in GF(2^8)
  (det [[1,γ],[γ,1]] = (1+γ)² in char 2).
* Every plane's n uncoupled symbols satisfy the s parity checks of a
  systematic Cauchy-RS(n,k) code.

Repair of node f = (x0,y0) reads the s^{m-1} planes with z_{y0} = x0;
every helper ships its *raw* symbols in those planes (optimal access,
β = α/s per helper), and the target solves the α×α plane-equation system
for f's symbols.  Bandwidth: (n-1)/(n-k) blocks — exactly Eq. (2); with
hierarchical placement (r < n) the cross-rack share is (n - n/r)/(n-k)
blocks, reproducing Theorem 1 for n-k=2, r=n/2.

The construction is *verified, not assumed*: __init__ searches a small γ
space until the MDS property and every node's repair both check out
(GF(2^8) is large enough that the first candidate virtually always works).
"""
from __future__ import annotations

import functools
import itertools

import numpy as np

from .. import gf
from ..code_base import ErasureCode, msr_repair_blocks
from ..repair import TARGET, RepairPlan, Send, build_target_order


@functools.lru_cache(maxsize=64)
def _construction(n: int, k: int) -> tuple[np.ndarray, int]:
    """Build (generator, gamma) for the coupled-layer MSR code."""
    s = n - k
    if n % s:
        raise ValueError(f"coupled-layer MSR needs (n-k) | n; got ({n},{k})")
    m = n // s
    alpha = s**m
    g_rs = gf.rs_generator(n, k)  # [I; P]
    h_rs = np.concatenate(  # H = [P | I], H @ G = 0 in char 2
        [g_rs[k:], np.eye(s, dtype=np.uint8)], axis=1
    )

    def digits(z: int) -> list[int]:
        out = []
        for _ in range(m):
            out.append(z % s)
            z //= s
        return out

    def with_digit(z: int, y: int, v: int) -> int:
        d = digits(z)
        d[y] = v
        out = 0
        for j in reversed(range(m)):
            out = out * s + d[j]
        return out

    def sym(i: int, z: int) -> int:
        return i * alpha + z

    for gamma in (2, 3, 7, 29, 113, 197):
        # constraint matrix over C-symbols: s checks per plane
        rows = []
        for z in range(alpha):
            dz = digits(z)
            # U(i; z) expressed over C-symbols
            u_expr = []
            for i in range(n):
                x, y = i % s, i // s
                expr = [(sym(i, z), 1)]
                if dz[y] != x:
                    j = y * s + dz[y]
                    zp = with_digit(z, y, x)
                    expr.append((sym(j, zp), gamma))
                u_expr.append(expr)
            for c in range(s):
                row = np.zeros(n * alpha, dtype=np.uint8)
                for i in range(n):
                    hc = int(h_rs[c, i])
                    if hc:
                        for col, coef in u_expr[i]:
                            row[col] ^= gf.gf_mul(hc, coef)
                rows.append(row)
        M = np.stack(rows, axis=0)  # (s*alpha, n*alpha)
        m_data, m_par = M[:, : k * alpha], M[:, k * alpha :]
        try:
            par_map = gf.gf_matmul(gf.gf_inv_matrix(m_par), m_data)
        except np.linalg.LinAlgError:
            continue
        gen = np.concatenate(
            [np.eye(k * alpha, dtype=np.uint8), par_map], axis=0
        )
        return gen, gamma
    raise RuntimeError(f"no feasible gamma for coupled-layer MSR({n},{k})")


class MSRCode(ErasureCode):
    name = "MSR"

    def __init__(self, n: int, k: int, r: int | None = None):
        s = n - k
        if n % s:
            raise ValueError(f"MSR (coupled-layer) needs (n-k) | n; got ({n},{k})")
        self.s = s
        self.m = n // s
        super().__init__(n, k, r if r is not None else n, alpha=s**self.m)

    def _build_generator(self) -> np.ndarray:
        gen, self.gamma = _construction(self.n, self.k)
        return gen

    # ------------------------------------------------------------------
    def _digits(self, z: int) -> list[int]:
        out, s = [], self.s
        for _ in range(self.m):
            out.append(z % s)
            z //= s
        return out

    def _repair_planes(self, failed: int) -> list[int]:
        x0, y0 = failed % self.s, failed // self.s
        return [z for z in range(self.alpha) if self._digits(z)[y0] == x0]

    @functools.lru_cache(maxsize=64)
    def _repair_decode(self, failed: int) -> np.ndarray:
        """Solve the plane equations for f's α symbols from helpers' raw
        repair-plane symbols.  Returns (alpha, (n-1)*beta) decode matrix
        with helper units ordered (node asc, plane asc)."""
        n, k, s, alpha = self.n, self.k, self.s, self.alpha
        planes = self._repair_planes(failed)
        beta = len(planes)
        helpers = [u for u in range(n) if u != failed]
        # column index of helper unit (u, z)
        ucol = {
            (u, z): hi * beta + zi
            for hi, u in enumerate(helpers)
            for zi, z in enumerate(planes)
        }
        # unknown index of f's symbols (all alpha planes)
        a_unk = np.zeros((s * beta, alpha), dtype=np.uint8)
        a_kno = np.zeros((s * beta, (n - 1) * beta), dtype=np.uint8)
        g_rs = gf.rs_generator(n, k)
        h_rs = np.concatenate([g_rs[k:], np.eye(s, dtype=np.uint8)], axis=1)
        gamma = self.gamma
        row = 0
        for z in planes:
            dz = self._digits(z)
            for c in range(s):
                for i in range(n):
                    hc = int(h_rs[c, i])
                    if not hc:
                        continue
                    x, y = i % s, i // s
                    # U(i; z) expansion
                    terms: list[tuple[int, int, int]] = [(i, z, 1)]  # (node, plane, coef)
                    if dz[y] != x:
                        j = y * s + dz[y]
                        zp = z - (dz[y] - x) * (s**y)  # with_digit(z, y, x)
                        terms.append((j, zp, gamma))
                    for node, plane, coef in terms:
                        v = gf.gf_mul(hc, coef)
                        if node == failed:
                            a_unk[row, plane] ^= v
                        else:
                            a_kno[row, ucol[(node, plane)]] ^= v
                row += 1
        # a_unk @ x_f = a_kno @ units  (char 2: moving terms is free)
        sol = gf.gf_solve(a_unk, a_kno)  # (alpha, (n-1)*beta)
        return np.ascontiguousarray(sol)

    def repair_plan(self, failed: int, rotation: int = 0) -> RepairPlan:
        planes = self._repair_planes(failed)
        beta = len(planes)
        sel = np.zeros((beta, self.alpha), dtype=np.uint8)
        for zi, z in enumerate(planes):
            sel[zi, z] = 1
        node_sends = [
            Send(u, TARGET, sel.copy()) for u in range(self.n) if u != failed
        ]
        return RepairPlan(
            failed=failed,
            placement=self.placement,
            alpha=self.alpha,
            node_sends=node_sends,
            relayer_sends=[],
            decode=self._repair_decode(failed),
            target_order=build_target_order(node_sends, []),
        )

    def theoretical_cross_rack_blocks(self) -> float:
        w = self.placement.nodes_per_rack
        return (self.n - w) / (self.n - self.k)

    def theoretical_total_blocks(self) -> float:
        return msr_repair_blocks(self.n, self.k)
