"""Shared strip-wise RS generator for both DRC families (paper §4.2/§4.3).

Each block is split into α subblocks; subblocks at the same offset across
the k data blocks form a *set*; set t is encoded with a systematic
(n, k) RS code G^(t).  Node i stores the i-th symbol of every set.

The per-set generators are *distinct*: set t's parity block is a Cauchy
matrix on its own evaluation points, P^(t)[q, j] = 1/(x^(t)_q + y_j) with
x^(t)_q = k + t·(n-k) + q.  Each set is individually MDS (Cauchy), but the
sets are *geometrically independent* — this matters for the Family-1
interference alignment.  Two weaker twists fail structurally:

* row scaling (P^(t) = D_t·P): h ⊥ δp ⟺ h ⊥ p, so all sets present
  byte-identical orthogonality geometry;
* column scaling (P^(t) = P·D_t): the ratios ρ_t(u) = P^(t)[q',u]/P^(t)[q,u]
  between parity rows are scaling-invariant, which forces every aligned
  repair unit's projection onto the failed node into a single direction
  (rank-1 m_proj — alignment can never complete).

The paper's §4.2 example likewise tunes coefficients per set.  Requires
k + α·(n-k) ≤ 256 (all paper configurations are far below).
"""
from __future__ import annotations

import numpy as np

from .. import gf
from ..code_base import ErasureCode


class StripwiseRS(ErasureCode):
    """Generator: node i's subblock t = set-t RS symbol i (α sets)."""

    def _build_generator(self) -> np.ndarray:
        n, k, a = self.n, self.k, self.alpha
        if k + a * (n - k) > 256:
            raise ValueError(f"GF(256) too small for stripwise ({n},{k})x{a}")
        ys = np.arange(k, dtype=np.uint8)
        self.set_gens: list[np.ndarray] = []
        for t in range(a):
            xs = np.arange(k + t * (n - k), k + (t + 1) * (n - k), dtype=np.uint8)
            parity = gf.cauchy_matrix(xs, ys)
            gt = np.concatenate([np.eye(k, dtype=np.uint8), parity], axis=0)
            self.set_gens.append(gt)
        g = np.zeros((n * a, k * a), dtype=np.uint8)
        for i in range(n):
            for t in range(a):
                g[i * a + t, t::a] = self.set_gens[t][i]
        return g
