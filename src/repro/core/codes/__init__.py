from .rs_code import RSCode
from .msr_clay import MSRCode
from .drc_family1 import DRCFamily1
from .drc_family2 import DRCFamily2
from .registry import make_code, PAPER_CODES

__all__ = [
    "RSCode",
    "MSRCode",
    "DRCFamily1",
    "DRCFamily2",
    "make_code",
    "PAPER_CODES",
]
