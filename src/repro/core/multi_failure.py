"""Beyond-paper extensions from the paper's §7 related work.

* Multi-failure repair (CORE [28] / §3.4's multi-node repair model): up
  to n-k concurrent failures are decoded from any k survivors; the
  traffic accounting mirrors the paper's reliability model (C = k per
  repaired node, all-surviving-rack-local blocks fetched first).
* Lazy repair (Total Recall [7] / Silberstein [45]): defer repair until
  the number of failures reaches a threshold, batching the decode cost.
* HACFS-style code switching [51]: keep *hot* stripes in a fast-repair
  code (DRC) and *cold* stripes in a low-redundancy code (RS),
  re-encoding on access-heat changes.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .code_base import ErasureCode
from .codes import make_code


# ---------------------------------------------------------- multi-failure
@dataclass
class MultiRepairReport:
    failed: list[int]
    helpers: list[int]
    cross_rack_blocks: float
    inner_rack_blocks: float


def multi_failure_repair(
    code: ErasureCode, failed: list[int], payloads: dict[int, np.ndarray]
) -> tuple[dict[int, np.ndarray], MultiRepairReport]:
    """Repair up to n-k concurrent failures.

    Single failure delegates to the layered plan (Eq. (3) traffic); multi
    failure decodes from k survivors at one target, preferring helpers in
    the first failed node's rack (the paper's C = k model).
    """
    if not failed:
        return {}, MultiRepairReport([], [], 0.0, 0.0)
    if len(failed) > code.n - code.k:
        raise ValueError(f"{len(failed)} failures exceed n-k={code.n - code.k}")
    if len(failed) == 1:
        f = failed[0]
        plan = code.repair_plan(f)
        t = plan.traffic_blocks()
        out = plan.execute(payloads)
        return {f: out}, MultiRepairReport(
            failed, plan.participants(), t["cross_rack_blocks"], t["inner_rack_blocks"]
        )
    pl = code.placement
    target_rack = pl.rack_of(failed[0])
    survivors = [i for i in range(code.n) if i not in failed]
    # prefer local helpers (free inner-rack transfer), then others
    helpers = sorted(
        survivors, key=lambda u: (pl.rack_of(u) != target_rack, u)
    )[: code.k]
    data = code.decode({i: payloads[i] for i in helpers})
    from . import gf

    coded = gf.gf_matmul(code.generator, data)
    a = code.alpha
    out = {f: coded[f * a : (f + 1) * a] for f in failed}
    cross = sum(1.0 for u in helpers if pl.rack_of(u) != target_rack)
    inner = len(helpers) - cross
    return out, MultiRepairReport(failed, helpers, cross, inner)


# -------------------------------------------------------------- lazy repair
@dataclass
class LazyRepairPolicy:
    """Defer repair until `threshold` failures accumulate (or a hot read
    forces a degraded repair).  Returns the action stream for tests and
    the simulator."""

    code_spec: tuple[str, int, int, int] = ("DRC", 9, 6, 3)
    threshold: int = 2
    failed: set[int] = field(default_factory=set)

    def on_failure(self, node: int) -> str:
        self.failed.add(node)
        n, k = self.code_spec[1], self.code_spec[2]
        if len(self.failed) >= n - k:
            return "repair_now"  # at fault-tolerance edge: must repair
        if len(self.failed) >= self.threshold:
            return "repair_batch"
        return "defer"

    def on_degraded_read(self, node: int) -> str:
        return "repair_single" if node in self.failed else "direct"

    def repaired(self, nodes: list[int]):
        self.failed -= set(nodes)

    def batched_saving_blocks(self) -> float:
        """Traffic saved vs eager repair: eager repairs each failure with
        a single-failure plan; lazy batches one k-block decode."""
        fam, n, k, r = self.code_spec
        code = make_code(fam, n, k, r)
        eager = len(self.failed) * (
            code.repair_plan(0).traffic_blocks()["total_blocks"]
        )
        lazy = float(k)
        return eager - lazy


# ----------------------------------------------------------- code switching
@dataclass
class CodeSwitcher:
    """HACFS-style two-code scheme: hot data in a fast-repair code, cold
    data in a compact code; switch on access-heat crossings."""

    hot_spec: tuple[str, int, int, int] = ("DRC", 9, 6, 3)
    cold_spec: tuple[str, int, int, int] = ("RS", 8, 6, 4)
    hot_threshold: float = 5.0  # EWMA accesses (decay 0.9 -> asymptote 10)
    heat: dict[int, float] = field(default_factory=dict)
    placement: dict[int, str] = field(default_factory=dict)  # stripe -> hot|cold

    def record_access(self, stripe: int, weight: float = 1.0):
        self.heat[stripe] = self.heat.get(stripe, 0.0) * 0.9 + weight

    def target_code(self, stripe: int) -> tuple[str, int, int, int]:
        hot = self.heat.get(stripe, 0.0) >= self.hot_threshold
        return self.hot_spec if hot else self.cold_spec

    def plan_switches(self) -> list[tuple[int, str]]:
        out = []
        for stripe, h in self.heat.items():
            want = "hot" if h >= self.hot_threshold else "cold"
            if self.placement.get(stripe, "cold") != want:
                out.append((stripe, want))
        return out

    def switch(self, stripe: int, blocks: np.ndarray) -> list[np.ndarray]:
        """Re-encode a stripe's data blocks into its target code."""
        fam, n, k, r = self.target_code(stripe)
        code = make_code(fam, n, k, r)
        want = "hot" if (fam, n, k, r) == self.hot_spec else "cold"
        self.placement[stripe] = want
        kb = blocks.reshape(code.k, -1)
        bb = kb.shape[1]
        pad = (-bb) % code.alpha
        if pad:
            kb = np.concatenate([kb, np.zeros((code.k, pad), np.uint8)], axis=1)
        return code.encode_blocks(kb)
