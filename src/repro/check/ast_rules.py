"""Dependency-free AST linter for the JAX/Pallas pitfalls this codebase
actually has.

Rules (ids are stable; see docs/architecture.md for the catalog):

* ``ast.jit-np`` — ``np.*`` *calls* inside a ``@jax.jit`` function or a
  Pallas kernel body: numpy executes at trace time on the host, silently
  constant-folding what looks like per-step work (FAIL).
* ``ast.jit-traced-if`` — a Python ``if`` whose test reads a non-static
  parameter of a jitted/kernel function: traced values have no truth
  value, or worse, the branch is burned in at trace time (WARN — the
  heuristic cannot see types).
* ``ast.jit-host-cast`` — ``float()``/``int()`` on values inside a
  jitted/kernel function: a host sync that blocks dispatch (FAIL).
* ``ast.host-sync`` — ``.block_until_ready()`` in library code: library
  paths must stay async; benchmarks time explicitly and are exempt
  (FAIL; suppress intentional syncs with a pragma).
* ``ast.span-no-with`` — ``obs.span(...)`` / ``tracer.span(...)`` called
  outside a ``with`` statement: the context manager is never entered, so
  the span is never recorded — or, entered manually, leaks the
  per-thread span stack on exceptions (FAIL).
* ``ast.mutable-default`` — mutable default arguments on functions and
  mutable class-level defaults on dataclass fields (use
  ``field(default_factory=...)``) (FAIL).
* ``ast.stale-pragma`` — a ``# check: ignore[...]`` pragma that no
  longer suppresses anything: the offending code was fixed or moved but
  the suppression stayed behind, silently masking future regressions on
  that line (WARN).
* ``ast.uninstrumented-entrypoint`` — a public function in ``serve/``
  or ``train/`` that does host-side work (numpy / filesystem calls, or
  mutating engine state) without ever opening an ``obs`` span or
  recording a metric: the remaining blind spots in the observability
  story.  Jitted/kernel functions, factories returning closures and
  private helpers are exempt; suppress deliberate host helpers with a
  pragma (WARN).

Suppression: append ``# check: ignore`` (everything) or
``# check: ignore[rule, rule]`` (specific rules, with or without the
``ast.`` prefix) to the offending line.  Pragmas are recognized only in
real comments (tokenize-level), so pragma examples inside docstrings —
like the ones above — are inert.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from pathlib import Path
from typing import Iterable

from .report import FAIL, WARN, Finding, LintRecord

L_NP_IN_JIT = "ast.jit-np"
L_TRACED_IF = "ast.jit-traced-if"
L_HOST_CAST = "ast.jit-host-cast"
L_HOST_SYNC = "ast.host-sync"
L_SPAN_WITH = "ast.span-no-with"
L_MUT_DEFAULT = "ast.mutable-default"
L_STALE_PRAGMA = "ast.stale-pragma"
L_UNINSTRUMENTED = "ast.uninstrumented-entrypoint"

ALL_LINT_RULES = (
    L_NP_IN_JIT, L_TRACED_IF, L_HOST_CAST, L_HOST_SYNC, L_SPAN_WITH,
    L_MUT_DEFAULT, L_STALE_PRAGMA, L_UNINSTRUMENTED,
)

_PRAGMA = re.compile(r"#\s*check:\s*ignore(?:\[([^\]]*)\])?")

# Paths (relative, substring match) where .block_until_ready is expected:
# benchmark/timing code blocks on results by design.
_SYNC_EXEMPT = ("benchmarks", "examples", "tests")

# Directories whose public entry points must self-instrument through
# repro.obs (matched as whole path parts, so launch/train.py is out).
_OBS_SCOPES = ("serve", "train")

# Call prefixes that mark host-side work: the function is an entry point
# the observability story should cover, not traced device compute.
_HOST_WORK_PREFIXES = (
    "np.", "numpy.", "os.", "json.", "zlib.", "time.", "io.", "shutil.",
)

# obs recording calls that count as instrumentation besides `with span`.
_OBS_RECORDERS = ("counter_add", "gauge_set", "record_span")


# --------------------------------------------------------------------------
# AST helpers
# --------------------------------------------------------------------------


def _dotted(node: ast.AST) -> str:
    """'jax.jit' for Attribute chains, 'jit' for Names, '' otherwise."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


_JIT_NAMES = {"jit", "jax.jit", "pjit", "jax.pjit"}
_PARTIAL_NAMES = {"partial", "functools.partial"}


def _jit_static_names(dec: ast.expr) -> set[str] | None:
    """If `dec` marks a jitted function, return its static arg names
    (possibly empty); otherwise None."""
    if _dotted(dec) in _JIT_NAMES:
        return set()
    if isinstance(dec, ast.Call):
        callee = _dotted(dec.func)
        inner: ast.expr | None = None
        kwargs = dec.keywords
        if callee in _JIT_NAMES:
            inner = dec.func
        elif callee in _PARTIAL_NAMES and dec.args:
            if _dotted(dec.args[0]) not in _JIT_NAMES:
                return None
            inner = dec.args[0]
        if inner is None:
            return None
        static: set[str] = set()
        for kw in kwargs:
            if kw.arg == "static_argnames":
                for const in ast.walk(kw.value):
                    if isinstance(const, ast.Constant) and isinstance(
                        const.value, str
                    ):
                        static.add(const.value)
        return static
    return None


def _pallas_kernel_names(tree: ast.AST) -> set[str]:
    """Names of functions passed (possibly via partial) to pallas_call."""
    out: set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if not _dotted(node.func).endswith("pallas_call"):
            continue
        if not node.args:
            continue
        kernel = node.args[0]
        if isinstance(kernel, ast.Call) and _dotted(kernel.func) in _PARTIAL_NAMES:
            kernel = kernel.args[0] if kernel.args else kernel
        name = _dotted(kernel)
        if name:
            out.add(name.rsplit(".", 1)[-1])
    return out


def _param_names(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> set[str]:
    a = fn.args
    params = [*a.posonlyargs, *a.args, *a.kwonlyargs]
    if a.vararg:
        params.append(a.vararg)
    if a.kwarg:
        params.append(a.kwarg)
    return {p.arg for p in params}


_MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
                     ast.SetComp)
_MUTABLE_CALLS = {"list", "dict", "set", "collections.defaultdict",
                  "defaultdict", "collections.OrderedDict", "OrderedDict"}


def _is_mutable_default(node: ast.expr | None) -> bool:
    if node is None:
        return False
    if isinstance(node, _MUTABLE_LITERALS):
        return True
    if isinstance(node, ast.Call):
        return _dotted(node.func) in _MUTABLE_CALLS
    return False


def _is_dataclass_decorated(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        if _dotted(target) in ("dataclass", "dataclasses.dataclass"):
            return True
    return False


# --------------------------------------------------------------------------
# The linter
# --------------------------------------------------------------------------


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, lines: list[str]):
        self.path = path
        self.lines = lines
        self.obs_scope = any(
            part in _OBS_SCOPES for part in Path(path).parts
        )
        self.findings: list[Finding] = []
        # pragma line -> rules a pragma on that line actually suppressed
        self.pragma_used: dict[int, set[str]] = {}
        self.kernel_names: set[str] = set()
        # stack of (is_jit_context, static_param_names, dynamic_param_names)
        self._jit_stack: list[tuple[bool, set[str], set[str]]] = []
        self._parents: dict[int, ast.AST] = {}

    # ------------------------------------------------------------- plumbing
    def run(self, tree: ast.AST) -> list[Finding]:
        self.kernel_names = _pallas_kernel_names(tree)
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[id(child)] = parent
        self.visit(tree)
        return self.findings

    def _emit(self, rule: str, severity: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 0)
        col = getattr(node, "col_offset", 0)
        if self._suppressed(rule, line):
            return
        self.findings.append(Finding(
            rule, severity, f"{self.path}:{line}:{col}: {message}",
            {"path": self.path, "line": line, "col": col},
        ))

    def _suppressed(self, rule: str, line: int) -> bool:
        if not (1 <= line <= len(self.lines)):
            return False
        m = _PRAGMA.search(self.lines[line - 1])
        if not m:
            return False
        if m.group(1) is None:
            self.pragma_used.setdefault(line, set()).add(rule)
            return True
        wanted = {r.strip() for r in m.group(1).split(",") if r.strip()}
        if rule in wanted or rule.removeprefix("ast.") in wanted:
            self.pragma_used.setdefault(line, set()).add(rule)
            return True
        return False

    def _in_jit(self) -> bool:
        return any(flag for flag, _, _ in self._jit_stack)

    def _dynamic_params(self) -> set[str]:
        out: set[str] = set()
        for flag, _static, dynamic in self._jit_stack:
            if flag:
                out |= dynamic
        return out

    # ------------------------------------------------------------ functions
    def _visit_function(self, node: ast.FunctionDef | ast.AsyncFunctionDef) -> None:
        static: set[str] | None = None
        for dec in node.decorator_list:
            static = _jit_static_names(dec)
            if static is not None:
                break
        if static is None and node.name in self.kernel_names:
            # pallas kernel body: positional params are Refs (dynamic);
            # keyword-only params are compile-time config bound via
            # functools.partial (the codebase's kernel idiom).
            static = {a.arg for a in node.args.kwonlyargs}
        is_jit = static is not None
        dynamic = _param_names(node) - (static or set()) if is_jit else set()
        # mutable default args (any function, jitted or not)
        defaults = [*node.args.defaults, *node.args.kw_defaults]
        for d in defaults:
            if _is_mutable_default(d):
                self._emit(
                    L_MUT_DEFAULT, FAIL, d,
                    f"mutable default argument in {node.name}() — shared "
                    f"across calls; use None or a tuple",
                )
        if not is_jit:
            self._check_uninstrumented(node)
        self._jit_stack.append((is_jit, static or set(), dynamic))
        self.generic_visit(node)
        self._jit_stack.pop()

    def _check_uninstrumented(
        self, node: ast.FunctionDef | ast.AsyncFunctionDef
    ) -> None:
        """WARN on public serve/train entry points with no obs hook."""
        if not self.obs_scope or node.name.startswith("_"):
            return
        if self._jit_stack:  # nested function: the outer def owns the span
            return
        for dec in node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            if _dotted(target).rsplit(".", 1)[-1] in (
                "property", "cached_property", "staticmethod",
            ):
                return
        nested = {
            c.name
            for c in ast.walk(node)
            if isinstance(c, (ast.FunctionDef, ast.AsyncFunctionDef))
            and c is not node
        }
        for sub in ast.walk(node):
            if (
                isinstance(sub, ast.Return)
                and isinstance(sub.value, ast.Name)
                and sub.value.id in nested
            ):
                return  # factory: the closure it builds is the real step
        if not self._does_host_work(node):
            return  # traced device compute: a span here would be wrong
        if self._opens_obs_hook(node):
            return
        self._emit(
            L_UNINSTRUMENTED, WARN, node,
            f"public entry point {node.name}() does host-side work but "
            f"never opens an obs span or records a metric — instrument "
            f"it (see core/repair.py) or suppress with a pragma",
        )

    def _does_host_work(
        self, node: ast.FunctionDef | ast.AsyncFunctionDef
    ) -> bool:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                callee = _dotted(sub.func)
                if callee == "open" or callee.startswith(_HOST_WORK_PREFIXES):
                    return True
            elif isinstance(sub, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = (
                    sub.targets
                    if isinstance(sub, ast.Assign)
                    else [sub.target]
                )
                for t in targets:
                    for a in ast.walk(t):
                        if (
                            isinstance(a, ast.Attribute)
                            and isinstance(a.value, ast.Name)
                            and a.value.id == "self"
                        ):
                            return True
        return False

    def _opens_obs_hook(
        self, node: ast.FunctionDef | ast.AsyncFunctionDef
    ) -> bool:
        for sub in ast.walk(node):
            if isinstance(sub, (ast.With, ast.AsyncWith)):
                for item in sub.items:
                    ce = item.context_expr
                    if isinstance(ce, ast.Call) and _dotted(
                        ce.func
                    ).rsplit(".", 1)[-1] == "span":
                        return True
            elif isinstance(sub, ast.Call):
                if _dotted(sub.func).rsplit(".", 1)[-1] in _OBS_RECORDERS:
                    return True
        return False

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_function(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._visit_function(node)

    # ------------------------------------------------------------- classes
    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        if _is_dataclass_decorated(node):
            for stmt in node.body:
                value = None
                if isinstance(stmt, ast.AnnAssign):
                    value = stmt.value
                elif isinstance(stmt, ast.Assign):
                    value = stmt.value
                if _is_mutable_default(value):
                    assert value is not None
                    self._emit(
                        L_MUT_DEFAULT, FAIL, value,
                        f"mutable default on dataclass {node.name} field — "
                        f"use field(default_factory=...)",
                    )
        self.generic_visit(node)

    # ----------------------------------------------------------------- ifs
    def visit_If(self, node: ast.If) -> None:
        if self._in_jit():
            dynamic = self._dynamic_params()
            used = {
                n.id
                for n in ast.walk(node.test)
                if isinstance(n, ast.Name) and n.id in dynamic
            }
            if used:
                self._emit(
                    L_TRACED_IF, WARN, node,
                    f"Python `if` on possibly-traced value(s) "
                    f"{sorted(used)} inside a jitted/kernel function — "
                    f"use jnp.where / lax.cond, or mark the arg static",
                )
        self.generic_visit(node)

    # --------------------------------------------------------------- calls
    def visit_Call(self, node: ast.Call) -> None:
        callee = _dotted(node.func)
        if self._in_jit():
            if callee.startswith(("np.", "numpy.")):
                self._emit(
                    L_NP_IN_JIT, FAIL, node,
                    f"`{callee}(...)` inside a jitted/kernel function runs "
                    f"on the host at trace time — use jnp",
                )
            if callee in ("float", "int") and node.args:
                self._emit(
                    L_HOST_CAST, FAIL, node,
                    f"`{callee}(...)` inside a jitted/kernel function "
                    f"forces a host sync — keep values on device",
                )
        if callee.endswith("block_until_ready") and not any(
            part in self.path for part in _SYNC_EXEMPT
        ):
            self._emit(
                L_HOST_SYNC, FAIL, node,
                "`.block_until_ready()` in library code blocks dispatch — "
                "benchmarks only, or suppress with a pragma if intentional",
            )
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr == "span"
            and not self._span_is_entered(node)
        ):
            self._emit(
                L_SPAN_WITH, FAIL, node,
                f"`{callee}(...)` outside a `with` — the span is never "
                f"recorded (or leaks the per-thread span stack)",
            )
        self.generic_visit(node)

    def _span_is_entered(self, node: ast.Call) -> bool:
        """span(...) calls must be with-items (or forwarded verbatim)."""
        parent = self._parents.get(id(node))
        if isinstance(parent, ast.withitem):
            return True
        if isinstance(parent, ast.Return):
            return True  # helper forwarding the context manager
        if isinstance(parent, ast.Call) and _dotted(parent.func).endswith(
            "enter_context"
        ):
            return True
        return False


# --------------------------------------------------------------------------
# Stale pragmas
# --------------------------------------------------------------------------


def _pragma_comments(src: str) -> list[tuple[int, str | None]]:
    """(line, rules-or-None) for every *real* pragma comment.

    Tokenize-level on purpose: a raw line regex would flag pragma
    examples embedded in docstrings (this module's own docstring has
    two).  Returns None rules for blanket ``# check: ignore``.
    """
    out: list[tuple[int, str | None]] = []
    try:
        tokens = tokenize.generate_tokens(io.StringIO(src).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _PRAGMA.search(tok.string)
            if m:
                out.append((tok.start[0], m.group(1)))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        pass  # unparsable files are already ast.syntax findings
    return out


def _stale_pragma_findings(
    src: str, path: str, pragma_used: dict[int, set[str]]
) -> list[Finding]:
    """WARN for every pragma (or listed rule) that suppressed nothing."""
    out: list[Finding] = []
    for line, rules_text in _pragma_comments(src):
        used = pragma_used.get(line, set())
        if rules_text is None:
            if used:
                continue
            msg = (
                f"{path}:{line}: stale `# check: ignore` — no rule fires "
                f"on this line anymore; drop the pragma so future "
                f"regressions are not silently masked"
            )
            out.append(Finding(
                L_STALE_PRAGMA, WARN, msg,
                {"path": path, "line": line, "rules": []},
            ))
            continue
        listed = [r.strip() for r in rules_text.split(",") if r.strip()]
        used_short = {r.removeprefix("ast.") for r in used}
        stale = [
            r for r in listed
            if r not in used and r.removeprefix("ast.") not in used_short
        ]
        if stale:
            out.append(Finding(
                L_STALE_PRAGMA, WARN,
                f"{path}:{line}: stale pragma — rule(s) {stale} no longer "
                f"fire on this line; drop them from the ignore list",
                {"path": path, "line": line, "rules": stale},
            ))
    return out


# --------------------------------------------------------------------------
# Entry points
# --------------------------------------------------------------------------


def lint_source(src: str, path: str = "<string>") -> list[Finding]:
    """Lint one source string; returns findings sorted by line."""
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Finding(
            "ast.syntax", FAIL, f"{path}:{e.lineno or 0}: {e.msg}",
            {"path": path, "line": e.lineno or 0},
        )]
    linter = _Linter(path, src.splitlines())
    findings = linter.run(tree)
    findings.extend(_stale_pragma_findings(src, path, linter.pragma_used))
    return sorted(findings, key=lambda f: int(f.witness.get("line", 0)))


def lint_file(path: str | Path) -> LintRecord:
    p = Path(path)
    return LintRecord(path=str(p), findings=lint_source(p.read_text(), str(p)))


def lint_paths(paths: Iterable[str | Path]) -> list[LintRecord]:
    return [lint_file(p) for p in paths]


def lint_tree(root: str | Path) -> list[LintRecord]:
    """Lint every ``*.py`` under `root`, sorted for stable reports."""
    files = sorted(Path(root).rglob("*.py"))
    return lint_paths(files)
