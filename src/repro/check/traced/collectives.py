"""Collective conformance: traced ppermutes vs the declared schedule.

The lowered layer (``check/lowered/spmd.py``) proves properties of the
*declared* ``SpmdRepairSpec``; these rules prove the *traced program*
implements exactly that declaration and nothing else:

* ``traced.coll.pairing`` — every ``ppermute`` in the jaxpr is
  well-formed on the pod axis: pairs in ``[0, r)``, no self-send, and
  source/destination pods each used at most once per equation
  (duplicate sources or destinations deadlock or drop data under
  XLA's permute semantics).
* ``traced.coll.permute-match`` — the traced permutes and the spec's
  ``permute_steps()`` match 1:1 (same (src, dst) pod pair, same row
  count): no orphan send the plan never scheduled, no scheduled step
  the program dropped.
* ``traced.coll.axis-scope`` — DoubleR's layering discipline as a mesh
  property: ``ppermute`` only ever crosses the ``pod`` (rack) axis and
  ``all_gather``/``psum`` only aggregate over the ``node`` (intra-rack)
  axis, so no collective smuggles bytes across the wrong boundary.
* ``traced.coll.cross-bytes`` — re-derive cross-rack bytes from the
  *compiled HLO* (``launch.hlo_analysis.parse_permutes``, pod = device
  // w) and gate them against ``plan.traffic_blocks()`` and, for DRC,
  the Eq. (3) closed form — the paper's bound as a property of the
  binary XLA will run.

The matcher (:func:`validate_pairs`, :func:`match_permutes`) is pure
data → data so hypothesis can drive it over random (n, k, r, w) shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from ..report import FAIL, Finding
from .base import COLL_FAMILY, as_witness, rule
from .capture import (
    REPAIR,
    CollectiveFootprint,
    GatherOp,
    PermuteOp,
    TracedProgram,
)

R_TC_PAIRING = "traced.coll.pairing"
R_TC_MATCH = "traced.coll.permute-match"
R_TC_AXIS = "traced.coll.axis-scope"
R_TC_BYTES = "traced.coll.cross-bytes"

Step = tuple[int, int, tuple[int, ...]]  # (src_pod, dst_pod, pool rows)


# ------------------------------------------------------------ pure matcher
def validate_pairs(
    pairs: tuple[tuple[int, int], ...], r: int
) -> list[str]:
    """Well-formedness defects of one permute's (src, dst) pod pairs."""
    defects: list[str] = []
    if not pairs:
        defects.append("empty pairing: permute moves no data")
    srcs = [s for s, _ in pairs]
    dsts = [d for _, d in pairs]
    for s, d in pairs:
        if not (0 <= s < r and 0 <= d < r):
            defects.append(f"pair ({s}, {d}) outside pod range [0, {r})")
        elif s == d:
            defects.append(f"self-send ({s}, {d}): bytes cross no rack")
    if len(set(srcs)) != len(srcs):
        defects.append(f"duplicate source pods {sorted(srcs)}")
    if len(set(dsts)) != len(dsts):
        defects.append(f"duplicate destination pods {sorted(dsts)}")
    return defects


@dataclasses.dataclass(frozen=True)
class PermuteMatch:
    """1:1 matching between traced permutes and declared steps."""

    matched: tuple[tuple[int, int], ...]  # (permute index, step index)
    orphan_permutes: tuple[int, ...]  # traced but never declared
    orphan_steps: tuple[int, ...]  # declared but never traced

    @property
    def complete(self) -> bool:
        return not self.orphan_permutes and not self.orphan_steps


def match_permutes(
    permutes: tuple[PermuteOp, ...], steps: tuple[Step, ...]
) -> PermuteMatch:
    """Match each traced permute to the declared step it implements.

    A permute implements step ``(src, dst, rows)`` when its (single)
    pair is exactly ``(src, dst)`` and its operand ships ``len(rows)``
    pool rows.  Each step is consumed at most once, so a duplicated
    permute becomes an orphan rather than double-matching.
    """
    free = dict(enumerate(steps))
    matched: list[tuple[int, int]] = []
    orphans: list[int] = []
    for pi, p in enumerate(permutes):
        hit = None
        for si, (src, dst, rows) in free.items():
            if p.pairs == ((src, dst),) and p.rows == len(rows):
                hit = si
                break
        if hit is None:
            orphans.append(pi)
        else:
            del free[hit]
            matched.append((pi, hit))
    return PermuteMatch(
        matched=tuple(matched),
        orphan_permutes=tuple(orphans),
        orphan_steps=tuple(sorted(free)),
    )


def _repair_meta(program: TracedProgram) -> Any | None:
    if program.kind != REPAIR:
        return None
    return program.meta.get("spec")


# ------------------------------------------------------------------- rules
@rule(R_TC_PAIRING, COLL_FAMILY)
def check_pairing(program: TracedProgram) -> list[Finding]:
    """Every traced ppermute is deadlock-free and self-send-free."""
    spec = _repair_meta(program)
    if spec is None:
        return []
    out: list[Finding] = []
    for i, p in enumerate(program.footprint.permutes):
        for defect in validate_pairs(p.pairs, spec.r):
            out.append(Finding(
                R_TC_PAIRING, FAIL,
                f"{program.name}: permute #{i} malformed — {defect}",
                as_witness(program=program.name, permute=i,
                           pairs=[list(pr) for pr in p.pairs], r=spec.r),
            ))
    return out


@rule(R_TC_MATCH, COLL_FAMILY)
def check_permute_match(program: TracedProgram) -> list[Finding]:
    """Traced permutes and declared schedule steps match 1:1."""
    spec = _repair_meta(program)
    if spec is None:
        return []
    permutes = program.footprint.permutes
    if any(validate_pairs(p.pairs, spec.r) for p in permutes):
        return []  # malformed pairing: traced.coll.pairing owns that
    steps = spec.permute_steps()
    m = match_permutes(permutes, steps)
    out: list[Finding] = []
    for pi in m.orphan_permutes:
        p = permutes[pi]
        out.append(Finding(
            R_TC_MATCH, FAIL,
            f"{program.name}: traced permute #{pi} "
            f"(pairs={list(p.pairs)}, rows={p.rows}) implements no "
            f"declared schedule step — bytes move that the plan never "
            f"scheduled",
            as_witness(program=program.name, permute=pi,
                       pairs=[list(pr) for pr in p.pairs], rows=p.rows),
        ))
    for si in m.orphan_steps:
        src, dst, rows = steps[si]
        out.append(Finding(
            R_TC_MATCH, FAIL,
            f"{program.name}: declared step #{si} (pod {src} -> {dst}, "
            f"{len(rows)} row(s)) has no traced permute — a scheduled "
            f"cross-rack ship was dropped",
            as_witness(program=program.name, step=si, src=src, dst=dst,
                       rows=len(rows)),
        ))
    return out


@rule(R_TC_AXIS, COLL_FAMILY)
def check_axis_scope(program: TracedProgram) -> list[Finding]:
    """ppermute crosses only `pod`; gathers/reductions stay on `node`."""
    spec = _repair_meta(program)
    if spec is None:
        return []
    out: list[Finding] = []
    for i, p in enumerate(program.footprint.permutes):
        if p.axes != ("pod",):
            out.append(Finding(
                R_TC_AXIS, FAIL,
                f"{program.name}: permute #{i} runs over axes {p.axes}, "
                f"not ('pod',) — cross-rack ships must use the rack axis",
                as_witness(program=program.name, permute=i,
                           axes=list(p.axes)),
            ))
    for i, g in enumerate(program.footprint.gathers):
        if g.axes != ("node",):
            out.append(Finding(
                R_TC_AXIS, FAIL,
                f"{program.name}: all_gather #{i} runs over axes "
                f"{g.axes}, not ('node',) — intra-rack aggregation must "
                f"never cross a pod boundary",
                as_witness(program=program.name, gather=i,
                           axes=list(g.axes)),
            ))
    for i, rd in enumerate(program.footprint.reduces):
        if not set(rd.axes) <= {"node"}:
            out.append(Finding(
                R_TC_AXIS, FAIL,
                f"{program.name}: {rd.name} #{i} reduces over axes "
                f"{rd.axes} — only the 'node' axis may aggregate",
                as_witness(program=program.name, reduce=i,
                           axes=list(rd.axes), op=rd.name),
            ))
    return out


@rule(R_TC_BYTES, COLL_FAMILY)
def check_cross_bytes(program: TracedProgram) -> list[Finding]:
    """Compiled-HLO cross-pod permute bytes == plan bytes == Eq. (3)."""
    spec = _repair_meta(program)
    if spec is None or not program.hlo:
        return []
    from repro.launch.hlo_analysis import cross_pod_permute_bytes

    plan = program.meta["plan"]
    code = program.meta["code"]
    sub = int(program.meta["sub_bytes"])
    w = int(program.meta["w"])
    hlo_bytes = cross_pod_permute_bytes(program.hlo, w)
    blocks = float(plan.traffic_blocks()["cross_rack_blocks"])
    plan_bytes = round(blocks * plan.alpha) * sub
    out: list[Finding] = []
    if hlo_bytes != plan_bytes:
        out.append(Finding(
            R_TC_BYTES, FAIL,
            f"{program.name}: compiled HLO ships {hlo_bytes} cross-pod "
            f"byte(s) but the plan accounts {plan_bytes} "
            f"({blocks:g} blocks x alpha={plan.alpha} x sub={sub})",
            as_witness(program=program.name, hlo_bytes=hlo_bytes,
                       plan_bytes=plan_bytes, blocks=blocks, sub=sub),
        ))
        return out
    try:
        bound = float(code.theoretical_cross_rack_blocks())
    except NotImplementedError:
        bound = None
    if bound is not None:
        bound_bytes = round(bound * plan.alpha) * sub
        if hlo_bytes != bound_bytes:
            out.append(Finding(
                R_TC_BYTES, FAIL,
                f"{program.name}: compiled HLO ships {hlo_bytes} "
                f"cross-pod byte(s); the Eq. (3) closed form gives "
                f"{bound_bytes} ({bound:g} blocks x alpha={plan.alpha} "
                f"x sub={sub})",
                as_witness(program=program.name, hlo_bytes=hlo_bytes,
                           bound_bytes=bound_bytes, bound_blocks=bound),
            ))
    return out


# --------------------------------------------------------------- mutations
# mutation name -> owning rule id; each corrupts the captured artifact of
# one real spmd_repair program (footprint or HLO text, whichever the
# owning rule actually reads) and must FAIL exactly its owner.
COLL_MUTATIONS: dict[str, str] = {
    "coll_orphan_permute": R_TC_MATCH,
    "coll_self_send": R_TC_PAIRING,
    "coll_axis_scope": R_TC_AXIS,
    "coll_hlo_bytes": R_TC_BYTES,
}


def coll_mutation_program(
    mutation: str, base: TracedProgram
) -> TracedProgram:
    """Apply one named corruption to a captured repair program."""
    fp = base.footprint
    if mutation == "coll_orphan_permute":
        # drop a scheduled ship: the declared step becomes an orphan
        if not fp.permutes:
            raise ValueError("base program traces no permutes")
        new_fp = dataclasses.replace(fp, permutes=fp.permutes[1:])
        return dataclasses.replace(base, footprint=new_fp)
    if mutation == "coll_self_send":
        # first permute sends a pod's bytes to itself
        if not fp.permutes:
            raise ValueError("base program traces no permutes")
        p = fp.permutes[0]
        q = p.pairs[0][0]
        bad = dataclasses.replace(p, pairs=((q, q),))
        new_fp = dataclasses.replace(fp, permutes=(bad, *fp.permutes[1:]))
        return dataclasses.replace(base, footprint=new_fp)
    if mutation == "coll_axis_scope":
        # an all_gather quietly aggregates over the rack axis
        spec = base.meta["spec"]
        bad_gather = GatherOp(axes=("pod",), axis_size=spec.r)
        new_fp = dataclasses.replace(
            fp, gathers=(*fp.gathers, bad_gather)
        )
        return dataclasses.replace(base, footprint=new_fp)
    if mutation == "coll_hlo_bytes":
        # the compiled module ships one cross-pod permute twice
        lines = base.hlo.splitlines()
        for i, line in enumerate(lines):
            if ("collective-permute" in line and "=" in line
                    and "source_target_pairs=" in line
                    and "collective-permute-done(" not in line):
                dup = lines[:i + 1] + [line] + lines[i + 1:]
                return dataclasses.replace(base, hlo="\n".join(dup))
        raise ValueError("base HLO contains no collective-permute")
    raise ValueError(f"unknown collective mutation {mutation!r}")


def coll_mutation_findings(
    mutation: str, base: TracedProgram
) -> list[Finding]:
    program = coll_mutation_program(mutation, base)
    findings: list[Finding] = []
    findings.extend(check_pairing(program))
    findings.extend(check_permute_match(program))
    findings.extend(check_axis_scope(program))
    findings.extend(check_cross_bytes(program))
    return findings


__all__ = [
    "COLL_MUTATIONS",
    "CollectiveFootprint",
    "PermuteMatch",
    "check_axis_scope",
    "check_cross_bytes",
    "check_pairing",
    "check_permute_match",
    "coll_mutation_findings",
    "coll_mutation_program",
    "match_permutes",
    "validate_pairs",
]
