"""Hot-path hygiene: host-transfer freedom and buffer donation.

* ``traced.hyg.host-transfer`` — the repair, serve, train and
  checkpoint programs must be pure device programs: any callback /
  infeed / outfeed primitive in the jaxpr stalls the hot path on a
  host round-trip (ROADMAP: "run as fast as the hardware allows").
  The AST linter catches *syntactic* host calls; this rule catches
  whatever actually survived into the traced program, through every
  function boundary.
* ``traced.hyg.donation`` — programs whose caller donates buffers
  (spmd repair payloads, checkpoint encode) must carry the donation
  through lowering: the StableHLO must mark the donated argument
  (``jax.buffer_donor`` / ``tf.aliasing_output``) and the compiled
  module must report an ``input_output_alias`` — otherwise
  encode/repair double-allocates the payload, which at checkpoint
  sizes is the difference between in-place and OOM.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from ..report import FAIL, Finding
from .base import HYG_FAMILY, as_witness, rule
from .capture import HOT_PATH, TracedProgram, _capture, iter_eqns

R_TH_HOST = "traced.hyg.host-transfer"
R_TH_DONATE = "traced.hyg.donation"

# Primitives that force a host round-trip mid-program.
HOST_TRANSFER_PRIMS = frozenset({
    "pure_callback",
    "io_callback",
    "callback",
    "python_callback",
    "debug_callback",
    "debug_print",
    "infeed",
    "outfeed",
})

_DONOR_MARKS = ("jax.buffer_donor", "tf.aliasing_output")


@rule(R_TH_HOST, HYG_FAMILY)
def check_host_transfer(program: TracedProgram) -> list[Finding]:
    """No callback/infeed/outfeed primitive anywhere in the jaxpr."""
    hits: dict[str, int] = {}
    for eqn in iter_eqns(program.jaxpr):
        name = eqn.primitive.name
        if name in HOST_TRANSFER_PRIMS:
            hits[name] = hits.get(name, 0) + 1
    return [
        Finding(
            R_TH_HOST, FAIL,
            f"{program.name}: jaxpr contains {count} `{prim}` "
            f"equation(s) — the hot path must never round-trip through "
            f"the host",
            as_witness(program=program.name, primitive=prim, count=count),
        )
        for prim, count in sorted(hits.items())
    ]


@rule(R_TH_DONATE, HYG_FAMILY)
def check_donation(program: TracedProgram) -> list[Finding]:
    """Donated buffers stay donated through StableHLO and compile."""
    if not program.donated or not program.stablehlo:
        return []
    out: list[Finding] = []
    if not any(mark in program.stablehlo for mark in _DONOR_MARKS):
        out.append(Finding(
            R_TH_DONATE, FAIL,
            f"{program.name}: argument(s) {list(program.donated)} are "
            f"donated but the StableHLO carries no donation marker "
            f"({' / '.join(_DONOR_MARKS)}) — the donation was lost in "
            f"lowering",
            as_witness(program=program.name,
                       donated=list(program.donated)),
        ))
    if program.hlo and "input_output_alias" not in program.hlo:
        out.append(Finding(
            R_TH_DONATE, FAIL,
            f"{program.name}: compiled module reports no "
            f"input_output_alias for donated argument(s) "
            f"{list(program.donated)} — encode/repair will "
            f"double-allocate the payload buffer",
            as_witness(program=program.name,
                       donated=list(program.donated)),
        ))
    return out


# --------------------------------------------------------------- mutations
HYG_MUTATIONS: dict[str, str] = {
    "hyg_callback": R_TH_HOST,
    "hyg_no_donation": R_TH_DONATE,
}


def callback_mutation_program() -> TracedProgram:
    """A hot-path program that sneaks a host callback into the step."""
    import jax
    import jax.numpy as jnp

    def bad(x: Any) -> Any:
        # e.g. a "quick" metrics hook left in the step function
        y = jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct((), jnp.float32), x
        )
        return y + 1.0

    x = jax.ShapeDtypeStruct((), jnp.float32)
    return _capture("mutant[hyg_callback]", HOT_PATH, bad, (x,))


def donation_mutation_program(base: TracedProgram) -> TracedProgram:
    """Strip the donation markers a captured donated program carries."""
    if not base.donated:
        raise ValueError("base program donates no arguments")
    stablehlo = base.stablehlo
    for mark in _DONOR_MARKS:
        stablehlo = stablehlo.replace(mark, "x.removed_attr")
    hlo = base.hlo.replace("input_output_alias", "removed_output_alias")
    return dataclasses.replace(base, stablehlo=stablehlo, hlo=hlo)


def hyg_mutation_findings(
    mutation: str, base: TracedProgram
) -> list[Finding]:
    if mutation == "hyg_callback":
        program = callback_mutation_program()
    elif mutation == "hyg_no_donation":
        program = donation_mutation_program(base)
    else:
        raise ValueError(f"unknown hygiene mutation {mutation!r}")
    findings: list[Finding] = []
    findings.extend(check_host_transfer(program))
    findings.extend(check_donation(program))
    return findings
