"""uint8 dtype-flow lattice over captured jaxprs.

GF(2^8) payload bytes must only ever be combined with XOR / table
gathers while they are in byte form; modular integer arithmetic
(``+ * -`` wrap mod 256) or a float promotion silently produces wrong
parities that no shape check can see.  The lowered layer has a
source-level taint pass (``lowered.pallas.check_gf_dtype``) but it
stops at function boundaries; here the program is fully inlined into a
jaxpr, so the taint follows payloads through every call layer —
``pjit``, ``shard_map``, ``scan``/``while``/``cond`` bodies, Pallas
kernel jaxprs — exactly as XLA sees them.

The lattice: a value is **tainted** when it (transitively) derives from
GF payload bytes *while still uint8*.  Sources are the program's
declared payload inputs and every uint8 constant (the GF mul/log
tables).  Taint propagates through bitwise and structural ops; it is
*cleared* by a conversion out of uint8 — the two sanctioned exits:
int32/int64 for table-gather indices and int8 for the bitplane kernel's
GF(2) planes (both leave the byte domain deliberately, and re-entering
it from clean values is plain data movement).  Violations:

* ``wrap-arith`` — an integer-ring op (add/sub/mul/dot/reduce_sum/...)
  consumes a tainted operand: GF addition is XOR, so this wraps.
* ``promotion`` — a tainted uint8 value is converted to a float dtype:
  payload bytes must never enter the float domain.

Loops (``scan``/``while``) run to a taint fixpoint over their carries.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from ..report import FAIL, Finding
from .base import DTYPE_FAMILY, as_witness, rule
from .capture import TracedProgram, _capture

R_TD_WRAP = "traced.dtype.wrap-arith"
R_TD_PROMO = "traced.dtype.promotion"
R_TD_OUT = "traced.dtype.payload-output"

WRAP = "wrap-arith"
PROMO = "promotion"

# Integer-ring primitives: a tainted operand here wraps mod 2^8 (or a
# widened ring), which is never GF(2^8) arithmetic.
_ARITH_PRIMS = frozenset({
    "add", "add_any", "sub", "mul", "div", "rem", "pow", "integer_pow",
    "dot_general", "reduce_sum", "reduce_prod", "cumsum", "cumprod",
})

# Structural / bitwise: taint flows through unchanged.
_HIGHER_ORDER = frozenset({
    "pjit", "closed_call", "core_call", "xla_call", "remat", "checkpoint",
    "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
})


@dataclasses.dataclass(frozen=True)
class DtypeViolation:
    kind: str  # wrap-arith | promotion
    primitive: str
    in_dtypes: tuple[str, ...]
    out_dtype: str


def _dtype(v: Any) -> str:
    return str(getattr(v.aval, "dtype", ""))


def _is_uint8(v: Any) -> bool:
    return _dtype(v) == "uint8"


def _first_sub_jaxpr(eqn: Any) -> Any | None:
    import jax

    for key in ("jaxpr", "call_jaxpr"):
        v = eqn.params.get(key)
        if isinstance(v, (jax.core.ClosedJaxpr, jax.core.Jaxpr)):
            return v
    return None


class _TaintInterp:
    """One abstract interpretation of a (closed) jaxpr."""

    def __init__(self) -> None:
        self.violations: set[DtypeViolation] = set()

    # -------------------------------------------------------------- plumbing
    def run_closed(
        self, closed: Any, in_taints: list[bool] | None = None
    ) -> list[bool]:
        jaxpr = getattr(closed, "jaxpr", closed)
        env: dict[Any, bool] = {}
        for cv in jaxpr.constvars:
            env[cv] = _is_uint8(cv)  # GF tables are payload-domain sources
        invars = jaxpr.invars
        if in_taints is None or len(in_taints) != len(invars):
            in_taints = [_is_uint8(v) for v in invars]
        for v, t in zip(invars, in_taints):
            env[v] = t
        for eqn in jaxpr.eqns:
            self._eqn(eqn, env)
        return [self._read(env, v) for v in jaxpr.outvars]

    def _read(self, env: dict[Any, bool], v: Any) -> bool:
        import jax

        if isinstance(v, jax.core.Literal):
            return False  # scalar literals (masks, init values) are clean
        return env.get(v, False)

    def _record(self, kind: str, eqn: Any) -> None:
        self.violations.add(DtypeViolation(
            kind=kind,
            primitive=eqn.primitive.name,
            in_dtypes=tuple(_dtype(v) for v in eqn.invars),
            out_dtype=_dtype(eqn.outvars[0]) if eqn.outvars else "",
        ))

    def _set_outs(self, env: dict[Any, bool], eqn: Any, taint: bool) -> None:
        for ov in eqn.outvars:
            # taint never lives on bool/float values: float arrival is the
            # promotion violation itself, and predicates carry no payload
            dt = _dtype(ov)
            env[ov] = taint and not (dt == "bool" or dt.startswith("float"))

    # ------------------------------------------------------------- dispatch
    def _eqn(self, eqn: Any, env: dict[Any, bool]) -> None:
        prim = eqn.primitive.name
        in_t = [self._read(env, v) for v in eqn.invars]

        if prim in _HIGHER_ORDER:
            sub = _first_sub_jaxpr(eqn)
            if sub is None:
                self._set_outs(env, eqn, any(in_t))
                return
            outs = self.run_closed(sub, in_t)
            self._map_outs(env, eqn, outs)
        elif prim == "shard_map":
            outs = self.run_closed(eqn.params["jaxpr"], in_t)
            self._map_outs(env, eqn, outs)
        elif prim == "scan":
            self._scan(eqn, env, in_t)
        elif prim == "while":
            self._while(eqn, env, in_t)
        elif prim == "cond":
            branches = eqn.params["branches"]
            per = [self.run_closed(br, in_t[1:]) for br in branches]
            outs = [any(col) for col in zip(*per)] if per else []
            self._map_outs(env, eqn, outs)
        elif prim == "pallas_call":
            self._pallas(eqn, in_t)
            self._set_outs(env, eqn, any(in_t))
        elif prim == "reduce":
            self._generic_reduce(eqn, env, in_t)
        elif prim in _ARITH_PRIMS:
            if any(in_t):
                self._record(WRAP, eqn)
            self._set_outs(env, eqn, False)
        elif prim == "convert_element_type":
            src_taint = in_t[0] if in_t else False
            src_u8 = bool(eqn.invars) and _is_uint8(eqn.invars[0])
            dst = _dtype(eqn.outvars[0]) if eqn.outvars else ""
            if src_taint and src_u8 and dst.startswith(("float", "bfloat")):
                self._record(PROMO, eqn)
                self._set_outs(env, eqn, False)
            elif src_taint and dst == "uint8":
                self._set_outs(env, eqn, True)
            else:
                # leaving uint8 is a sanctioned exit (indices / bitplanes)
                self._set_outs(env, eqn, False)
        elif prim == "select_n":
            self._set_outs(env, eqn, any(in_t[1:]))  # predicate carries none
        else:
            self._set_outs(env, eqn, any(in_t))

    def _map_outs(self, env: dict[Any, bool], eqn: Any, outs: list[bool]) -> None:
        for i, ov in enumerate(eqn.outvars):
            t = outs[i] if i < len(outs) else False
            dt = _dtype(ov)
            env[ov] = t and not (dt == "bool" or dt.startswith("float"))

    # --------------------------------------------------------- higher-order
    def _scan(self, eqn: Any, env: dict[Any, bool], in_t: list[bool]) -> None:
        body = eqn.params["jaxpr"]
        nc = int(eqn.params.get("num_consts", 0))
        ncar = int(eqn.params.get("num_carry", 0))
        cur = list(in_t)
        outs: list[bool] = []
        for _ in range(ncar + 2):  # taint only grows; small fixpoint
            outs = self.run_closed(body, cur)
            carry_out = outs[:ncar]
            nxt = list(in_t)
            for i in range(ncar):
                nxt[nc + i] = in_t[nc + i] or carry_out[i]
            if nxt == cur:
                break
            cur = nxt
        self._map_outs(env, eqn, outs)

    def _while(self, eqn: Any, env: dict[Any, bool], in_t: list[bool]) -> None:
        cn = int(eqn.params.get("cond_nconsts", 0))
        bn = int(eqn.params.get("body_nconsts", 0))
        body = eqn.params["body_jaxpr"]
        cond = eqn.params["cond_jaxpr"]
        carry = list(in_t[cn + bn:])
        body_consts = in_t[cn:cn + bn]
        for _ in range(len(carry) + 2):
            outs = self.run_closed(body, body_consts + carry)
            nxt = [c or o for c, o in zip(carry, outs)]
            if nxt == carry:
                break
            carry = nxt
        self.run_closed(cond, in_t[:cn] + carry)
        self._map_outs(env, eqn, carry)

    def _generic_reduce(
        self, eqn: Any, env: dict[Any, bool], in_t: list[bool]
    ) -> None:
        """`lax.reduce` with an explicit combiner: an XOR/AND/OR
        combiner is GF-legal and propagates taint; an arithmetic
        combiner on a tainted operand wraps."""
        comb = eqn.params.get("jaxpr")
        jaxpr = getattr(comb, "jaxpr", comb)
        arith = jaxpr is not None and any(
            e.primitive.name in _ARITH_PRIMS for e in jaxpr.eqns
        )
        if arith and any(in_t):
            self._record(WRAP, eqn)
            self._set_outs(env, eqn, False)
        else:
            self._set_outs(env, eqn, any(in_t))

    def _pallas(self, eqn: Any, in_t: list[bool]) -> None:
        """Kernel jaxprs operate on Refs: seed input refs with the call
        operands' taint, then interpret get/swap as ref reads/writes."""
        kernel = eqn.params.get("jaxpr")
        if kernel is None:
            return
        jaxpr = getattr(kernel, "jaxpr", kernel)
        refs = list(jaxpr.invars)
        env: dict[Any, bool] = {}
        for cv in jaxpr.constvars:
            env[cv] = _is_uint8(cv)
        for i, ref in enumerate(refs):
            env[ref] = in_t[i] if i < len(in_t) else False
        for keqn in jaxpr.eqns:
            name = keqn.primitive.name
            if name in ("get", "masked_load"):
                t = self._read(env, keqn.invars[0])
                for ov in keqn.outvars:
                    env[ov] = t
            elif name in ("swap", "masked_swap", "addupdate"):
                ref, val = keqn.invars[0], keqn.invars[1]
                stored = self._read(env, val)
                env[ref] = self._read(env, ref) or stored
                for ov in keqn.outvars:
                    env[ov] = stored
            else:
                self._eqn(keqn, env)


def dtype_flow_violations(program: TracedProgram) -> list[DtypeViolation]:
    """Run the lattice over one captured program."""
    interp = _TaintInterp()
    jaxpr = getattr(program.jaxpr, "jaxpr", program.jaxpr)
    seeds = [
        i in program.payload_invars and _is_uint8(v)
        for i, v in enumerate(jaxpr.invars)
    ]
    interp.run_closed(program.jaxpr, seeds)
    return sorted(
        interp.violations, key=lambda v: (v.kind, v.primitive, v.in_dtypes)
    )


# ------------------------------------------------------------------- rules
@rule(R_TD_WRAP, DTYPE_FAMILY)
def check_wrap_arith(program: TracedProgram) -> list[Finding]:
    """No integer-ring arithmetic ever consumes a GF payload byte."""
    out: list[Finding] = []
    for v in dtype_flow_violations(program):
        if v.kind != WRAP:
            continue
        out.append(Finding(
            R_TD_WRAP, FAIL,
            f"{program.name}: `{v.primitive}` consumes GF payload bytes "
            f"({', '.join(v.in_dtypes)}) — integer arithmetic wraps mod "
            f"2^8; GF addition is XOR",
            as_witness(program=program.name, primitive=v.primitive,
                       in_dtypes=list(v.in_dtypes), out_dtype=v.out_dtype),
        ))
    return out


@rule(R_TD_PROMO, DTYPE_FAMILY)
def check_promotion(program: TracedProgram) -> list[Finding]:
    """No GF payload byte is ever promoted to a float dtype."""
    out: list[Finding] = []
    for v in dtype_flow_violations(program):
        if v.kind != PROMO:
            continue
        out.append(Finding(
            R_TD_PROMO, FAIL,
            f"{program.name}: GF payload bytes promoted to {v.out_dtype} "
            f"via `{v.primitive}` — payloads must never enter the float "
            f"domain",
            as_witness(program=program.name, primitive=v.primitive,
                       out_dtype=v.out_dtype),
        ))
    return out


@rule(R_TD_OUT, DTYPE_FAMILY)
def check_payload_output(program: TracedProgram) -> list[Finding]:
    """Declared payload outputs leave the program as uint8."""
    jaxpr = getattr(program.jaxpr, "jaxpr", program.jaxpr)
    out: list[Finding] = []
    for idx in program.payload_outvars:
        if idx >= len(jaxpr.outvars):
            continue
        dt = _dtype(jaxpr.outvars[idx])
        if dt != "uint8":
            out.append(Finding(
                R_TD_OUT, FAIL,
                f"{program.name}: payload output {idx} has dtype {dt}, "
                f"expected uint8 — the byte domain must be preserved "
                f"end-to-end",
                as_witness(program=program.name, outvar=idx, dtype=dt),
            ))
    return out


# --------------------------------------------------------------- mutations
# mutation name -> owning rule id; each builds a deliberately wrong GF
# program, retraces it, and must FAIL exactly its owner.
DTYPE_MUTATIONS: dict[str, str] = {
    "dtype_wrap_arith": R_TD_WRAP,
    "dtype_float_promote": R_TD_PROMO,
    "dtype_narrow_output": R_TD_OUT,
}


def dtype_mutation_program(mutation: str) -> TracedProgram:
    """Trace the mutated GF-matmul variant owned by `mutation`."""
    import jax
    import jax.numpy as jnp

    from repro.core.gf_jax import gf_matmul_jnp

    m = jax.ShapeDtypeStruct((3, 6), jnp.uint8)
    x = jax.ShapeDtypeStruct((6, 256), jnp.uint8)
    if mutation == "dtype_wrap_arith":
        def bad(m: Any, x: Any) -> Any:
            # integer + instead of XOR when combining parities: wraps
            return gf_matmul_jnp(m, x) + gf_matmul_jnp(m, x)
    elif mutation == "dtype_float_promote":
        def bad(m: Any, x: Any) -> Any:
            # payload round-trips through float32 before encoding
            return gf_matmul_jnp(m, x.astype(jnp.float32).astype(jnp.uint8))
    elif mutation == "dtype_narrow_output":
        def bad(m: Any, x: Any) -> Any:
            # payload leaves the program as int16 instead of uint8
            return gf_matmul_jnp(m, x).astype(jnp.int16)
    else:
        raise ValueError(f"unknown dtype mutation {mutation!r}")
    return _capture(
        f"mutant[{mutation}]", "kernel", bad, (m, x),
        payload_invars=(0, 1), payload_outvars=(0,),
    )


def dtype_mutation_findings(mutation: str) -> list[Finding]:
    program = dtype_mutation_program(mutation)
    findings: list[Finding] = []
    findings.extend(check_wrap_arith(program))
    findings.extend(check_promotion(program))
    findings.extend(check_payload_output(program))
    return findings
