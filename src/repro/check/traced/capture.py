"""Capture the real executables as analyzable artifacts.

A :class:`TracedProgram` bundles everything the traced-layer rules
consume for one entry point:

* the **jaxpr** (``jax.make_jaxpr`` on the exact function the runtime
  jits, at the runtime's shapes/dtypes),
* the **StableHLO** text (``.lower().as_text()``) and the **compiled
  HLO** text (``.compile().as_text()``) where the program is small
  enough to lower — the donation markers and the partitioned
  collective-permute instructions only exist there,
* a :class:`CollectiveFootprint` — the ppermute/all_gather/psum
  equations distilled to pure data so the conformance rules (and their
  mutations) operate on a corruptible artifact, mirroring how the
  lowered layer corrupts ``SpmdRepairSpec``.

Capture never executes the program: tracing is abstract
(``ShapeDtypeStruct`` inputs) and compile is CPU-ahead-of-time, so the
sweep is cheap enough for CI.  Mesh-shaped programs
(:func:`capture_spmd_repair`) need ``r*w`` devices —
``tools/run_check.py`` forces a host-platform device count before jax
initializes; in-process test suites must use a subprocess instead.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator

import numpy as np


# --------------------------------------------------------------- jaxpr walk
def _sub_jaxprs(eqn: Any) -> Iterator[Any]:
    """Inner (plain) jaxprs reachable from one equation's params."""
    import jax

    for v in eqn.params.values():
        vals = v if isinstance(v, (list, tuple)) else (v,)
        for item in vals:
            if isinstance(item, jax.core.ClosedJaxpr):
                yield item.jaxpr
            elif isinstance(item, jax.core.Jaxpr):
                yield item


def iter_eqns(jaxpr: Any) -> Iterator[Any]:
    """All equations of a (closed) jaxpr, recursing into sub-jaxprs
    (pjit, shard_map, scan, cond, pallas_call, ...)."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)  # accept ClosedJaxpr or Jaxpr
    for eqn in inner.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub)


def primitive_names(jaxpr: Any) -> set[str]:
    return {eqn.primitive.name for eqn in iter_eqns(jaxpr)}


def _axis_names(raw: Any) -> tuple[str, ...]:
    if isinstance(raw, (tuple, list)):
        return tuple(str(a) for a in raw)
    return (str(raw),)


# ---------------------------------------------------------------- footprint
@dataclasses.dataclass(frozen=True)
class PermuteOp:
    """One ``ppermute`` equation distilled: axis, (src, dst) pairs, and
    the per-device operand (rows shipped x bytes)."""

    axes: tuple[str, ...]
    pairs: tuple[tuple[int, int], ...]
    rows: int
    nbytes: int
    dtype: str


@dataclasses.dataclass(frozen=True)
class GatherOp:
    """One ``all_gather`` equation distilled."""

    axes: tuple[str, ...]
    axis_size: int


@dataclasses.dataclass(frozen=True)
class ReduceOp:
    """One ``psum``/``pmax``/``pmin`` equation distilled."""

    name: str
    axes: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class CollectiveFootprint:
    """Every cross-device collective the captured jaxpr performs."""

    permutes: tuple[PermuteOp, ...] = ()
    gathers: tuple[GatherOp, ...] = ()
    reduces: tuple[ReduceOp, ...] = ()


def extract_footprint(jaxpr: Any) -> CollectiveFootprint:
    """Distill the collectives out of a (closed) jaxpr."""
    permutes: list[PermuteOp] = []
    gathers: list[GatherOp] = []
    reduces: list[ReduceOp] = []
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name == "ppermute":
            aval = eqn.invars[0].aval
            shape = tuple(int(d) for d in aval.shape)
            nbytes = int(np.prod(shape)) * np.dtype(str(aval.dtype)).itemsize
            permutes.append(PermuteOp(
                axes=_axis_names(eqn.params["axis_name"]),
                pairs=tuple(
                    (int(s), int(d)) for s, d in eqn.params["perm"]
                ),
                rows=shape[0] if shape else 1,
                nbytes=nbytes,
                dtype=str(aval.dtype),
            ))
        elif name == "all_gather":
            gathers.append(GatherOp(
                axes=_axis_names(eqn.params["axis_name"]),
                axis_size=int(eqn.params["axis_size"]),
            ))
        elif name in ("psum", "pmax", "pmin"):
            raw = eqn.params.get("axes", eqn.params.get("axis_name", ()))
            reduces.append(ReduceOp(name=name, axes=_axis_names(raw)))
    return CollectiveFootprint(
        permutes=tuple(permutes),
        gathers=tuple(gathers),
        reduces=tuple(reduces),
    )


# ------------------------------------------------------------------ program
REPAIR = "repair"
KERNEL = "kernel"
HOT_PATH = "hot-path"
CHECKPOINT = "checkpoint"

PROGRAM_KINDS = (REPAIR, KERNEL, HOT_PATH, CHECKPOINT)


@dataclasses.dataclass
class TracedProgram:
    """One captured executable plus everything the rules need."""

    name: str  # e.g. "spmd_repair[DRC(6,4,3) failed=0]"
    kind: str  # repair | kernel | hot-path | checkpoint
    jaxpr: Any  # ClosedJaxpr
    footprint: CollectiveFootprint
    stablehlo: str = ""  # lowered module text ("" when not lowered)
    hlo: str = ""  # compiled module text ("" when not compiled)
    donated: tuple[int, ...] = ()  # argnums the caller donates
    payload_invars: tuple[int, ...] = ()  # flat invar indices holding GF bytes
    payload_outvars: tuple[int, ...] = ()  # flat outvar indices holding GF bytes
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.kind not in PROGRAM_KINDS:
            raise ValueError(f"bad program kind {self.kind!r}")


def require_devices(n: int) -> None:
    import jax

    have = jax.device_count()
    if have < n:
        raise RuntimeError(
            f"traced capture needs {n} devices, found {have}; run through "
            f"tools/run_check.py or set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            f"jax initializes"
        )


def _capture(
    name: str,
    kind: str,
    fn: Callable[..., Any],
    args: tuple[Any, ...],
    *,
    payload_invars: tuple[int, ...] = (),
    payload_outvars: tuple[int, ...] = (),
    donate_argnums: tuple[int, ...] = (),
    lower: bool = False,
    meta: dict[str, Any] | None = None,
) -> TracedProgram:
    import jax

    jaxpr = jax.make_jaxpr(fn)(*args)
    stablehlo = hlo = ""
    if lower:
        jitted = fn if hasattr(fn, "lower") else jax.jit(
            fn, donate_argnums=donate_argnums
        )
        lowered = jitted.lower(*args)
        stablehlo = lowered.as_text()
        hlo = lowered.compile().as_text()
    return TracedProgram(
        name=name,
        kind=kind,
        jaxpr=jaxpr,
        footprint=extract_footprint(jaxpr),
        stablehlo=stablehlo,
        hlo=hlo,
        donated=donate_argnums,
        payload_invars=payload_invars,
        payload_outvars=payload_outvars,
        meta=meta or {},
    )


# ------------------------------------------------------- repair entry point
def capture_spmd_repair(
    family: str,
    n: int,
    k: int,
    r: int,
    *,
    failed: int = 0,
    sub: int = 256,
    donate: bool = True,
) -> TracedProgram:
    """Trace + lower + compile the exact program ``spmd_repair`` runs."""
    import jax
    import jax.numpy as jnp

    from repro.core.codes import make_code
    from repro.dist.collectives import make_spmd_repair, plan_to_spmd
    from jax.sharding import PartitionSpec as P

    code = make_code(family, n, k, r=r)
    plan = code.repair_plan(failed)
    spec = plan_to_spmd(code, plan)
    require_devices(spec.r * spec.w)
    mesh = jax.make_mesh((spec.r, spec.w), ("pod", "node"))
    fn = jax.shard_map(
        make_spmd_repair(spec), mesh=mesh,
        in_specs=P(("pod", "node")), out_specs=P(("pod", "node")),
    )
    x = jax.ShapeDtypeStruct((n, spec.alpha, sub), jnp.uint8)
    return _capture(
        f"spmd_repair[{family}({n},{k},{r}) failed={failed}]",
        REPAIR,
        fn,
        (x,),
        payload_invars=(0,),
        payload_outvars=(0,),
        donate_argnums=(0,) if donate else (),
        lower=True,
        meta={
            "spec": spec, "plan": plan, "code": code, "sub_bytes": sub,
            "w": spec.w,
        },
    )


# ------------------------------------------------------- kernel call sites
def capture_gf_ref(rows: int = 3, k: int = 6, sub: int = 256) -> TracedProgram:
    """The pure-jnp GF matmul oracle, as called by decode/encode paths."""
    import jax
    import jax.numpy as jnp

    from repro.core.gf_jax import gf_matmul_jnp

    m = jax.ShapeDtypeStruct((rows, k), jnp.uint8)
    x = jax.ShapeDtypeStruct((k, sub), jnp.uint8)
    return _capture(
        f"gf_matmul_jnp[{rows}x{k}x{sub}]", KERNEL, gf_matmul_jnp, (m, x),
        payload_invars=(0, 1), payload_outvars=(0,),
    )


def capture_gf_pallas(
    rows: int = 3, k: int = 6, sub: int = 1024, block_b: int = 512
) -> TracedProgram:
    """The Pallas bitplane kernel call site (kernel jaxpr included)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.gf_matmul import gf_matmul_pallas
    from repro.kernels.ops import bit_expand

    mb_np = bit_expand(
        np.arange(rows * k, dtype=np.uint8).reshape(rows, k)
    )
    mb = jax.ShapeDtypeStruct(mb_np.shape, jnp.int8)
    x = jax.ShapeDtypeStruct((k, sub), jnp.uint8)

    def call(mb: Any, x: Any) -> Any:
        return gf_matmul_pallas(mb, x, block_b=block_b, interpret=True)

    return _capture(
        f"gf_matmul_pallas[{rows}x{k}x{sub}]", KERNEL, call, (mb, x),
        payload_invars=(1,), payload_outvars=(0,),
    )


# ----------------------------------------------------- serve / train paths
def capture_serve_prefill(
    arch: str = "xlstm_125m", batch: int = 2, seq: int = 16
) -> TracedProgram:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke
    from repro.models import backbone
    from repro.serve.serve_step import make_prefill_step

    cfg = get_smoke(arch)
    params, _ = backbone.init_model(jax.random.key(0), cfg)
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    fn = make_prefill_step(cfg, chunk=seq)
    return _capture(
        f"prefill_step[{cfg.name}]", HOT_PATH, fn,
        (params, {"tokens": tok, "labels": tok}),
    )


def capture_serve_decode(
    arch: str = "xlstm_125m", batch: int = 2, kv_len: int = 32
) -> TracedProgram:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke
    from repro.models import backbone
    from repro.serve.serve_step import make_decode_step

    cfg = get_smoke(arch)
    params, _ = backbone.init_model(jax.random.key(0), cfg)
    state, _ = backbone.init_decode_state(cfg, batch, kv_len)
    tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    fn = make_decode_step(cfg)
    return _capture(
        f"serve_step[{cfg.name}]", HOT_PATH, fn, (params, state, tok, 0),
    )


def capture_train_step(
    arch: str = "xlstm_125m", batch: int = 2, seq: int = 16
) -> TracedProgram:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke
    from repro.train.train_step import (
        TrainConfig,
        init_train_state,
        make_train_step,
    )

    cfg = get_smoke(arch)
    # fused_xent needs an ambient (pod, data) mesh; the mesh-free variant
    # traces the same backbone/optimizer path, which is what the hygiene
    # and dtype rules analyze.
    tcfg = TrainConfig(fused_xent=False, attn_chunk=seq)
    params, opt, _ = init_train_state(jax.random.key(1), cfg, tcfg)
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    fn = make_train_step(cfg, tcfg)
    return _capture(
        f"train_step[{cfg.name}]", HOT_PATH, fn,
        (params, opt, {"tokens": tok, "labels": tok}, 0),
    )


# ------------------------------------------------------- checkpoint encode
def capture_checkpoint_encode(
    family: str = "DRC", n: int = 6, k: int = 4, r: int = 3, sub: int = 256
) -> TracedProgram:
    """The donated systematic-encode program checkpointing runs."""
    import jax
    import jax.numpy as jnp

    from repro.core.codes import make_code
    from repro.train.checkpoint import make_encode_step

    code = make_code(family, n, k, r=r)
    fn = make_encode_step(code, sub)
    coded = jax.ShapeDtypeStruct((code.n * code.alpha, sub), jnp.uint8)
    return _capture(
        f"ckpt_encode[{family}({n},{k},{r}) sub={sub}]", CHECKPOINT, fn,
        (coded,),
        payload_invars=(0,),
        payload_outvars=(0,),
        donate_argnums=(0,),
        lower=True,
        meta={"code": code, "sub_bytes": sub},
    )
