"""Rule registry for the traced-layer analyzers.

Third verification layer: ``repro.check.plan`` proves the repair DAG,
``repro.check.lowered`` proves the static lowering artifacts, and this
package proves the *programs XLA actually runs* — jaxprs captured from
the real entry points plus their StableHLO/HLO text.

Unlike the lowered registry (where a family identifies an artifact
type), traced rules are grouped by *analysis* because every rule can in
principle run over any captured program:

* ``dtype-flow`` — the uint8 taint lattice over the jaxpr
  (:mod:`.dtype_flow`),
* ``collective`` — ppermute/all_gather conformance against the
  ``SpmdRepairSpec`` schedule plus HLO byte accounting
  (:mod:`.collectives`),
* ``hygiene`` — host-transfer freedom and donation/aliasing
  (:mod:`.hygiene`).

``rule(rule_id, family)`` registers under a stable id; the sweep, the
mutation self-test and the docs catalog all read ``TRACED_RULES``.
Ids are namespaced ``traced.<group>.<name>``.
"""
from __future__ import annotations

from typing import Any, Callable, TypeVar

from ..report import Finding

TracedRuleFn = Callable[..., list[Finding]]
_F = TypeVar("_F", bound=TracedRuleFn)

DTYPE_FAMILY = "dtype-flow"
COLL_FAMILY = "collective"
HYG_FAMILY = "hygiene"

TRACED_FAMILIES = (DTYPE_FAMILY, COLL_FAMILY, HYG_FAMILY)

# rule id -> (family, rule fn); populated by the analysis modules at import
TRACED_RULES: dict[str, tuple[str, TracedRuleFn]] = {}


def rule(rule_id: str, family: str) -> Callable[[_F], _F]:
    """Register a traced-layer rule under a stable id."""
    if family not in TRACED_FAMILIES:
        raise ValueError(f"unknown traced family {family!r}")

    def deco(fn: _F) -> _F:
        if rule_id in TRACED_RULES:
            raise ValueError(f"duplicate traced rule id {rule_id!r}")
        TRACED_RULES[rule_id] = (family, fn)
        return fn

    return deco


def rules_for(family: str) -> dict[str, TracedRuleFn]:
    """The registered rules of one analysis group, id -> fn."""
    return {
        rid: fn for rid, (fam, fn) in TRACED_RULES.items() if fam == family
    }


def fail_rules(findings: list[Finding]) -> set[str]:
    """Distinct rule ids that FAILed — the mutation self-test's currency."""
    from ..report import FAIL

    return {f.rule for f in findings if f.severity == FAIL}


def as_witness(**kw: Any) -> dict[str, Any]:
    """Tiny helper keeping witness construction one line at call sites."""
    return kw
