"""Static analysis over *traced* programs (``repro.check.traced``).

Third verification layer.  ``repro.check.plan`` proves the repair DAG
optimal, ``repro.check.lowered`` proves the declared lowering artifacts
preserve that optimality; this package proves the **programs XLA
actually runs** do too — it captures the real entry points
(:mod:`.capture`: the ``spmd_repair`` shard_map program for every
REGISTRY_SWEEP DRC shape + an RS contrast, both GF matmul kernels, the
serve prefill/decode steps, the train step, the donated checkpoint
encode) and runs dataflow rules over their jaxprs and StableHLO/HLO:

* :mod:`.dtype_flow` — uint8 taint lattice: GF(2^8) payload bytes are
  never wrapped by ring arithmetic, never promoted to float, and leave
  the program as uint8.
* :mod:`.collectives` — every traced ``ppermute`` matches one declared
  ``SpmdRepairSpec`` schedule step (pairing-valid, deadlock-free, right
  axis), and cross-rack bytes re-derived from the *compiled HLO* equal
  ``plan.traffic_blocks()`` and the Eq. (3) closed form.
* :mod:`.hygiene` — no host callback/infeed/outfeed in any hot-path
  jaxpr; buffer donation survives into StableHLO + input_output_alias.

Every rule has a paired mutation in ``TRACED_MUTATIONS``;
:func:`self_test_traced` corrupts one captured artifact per mutation
and demands the corruption FAIL *exactly* its owning rule (same
contract as ``self_test_lowered``).  Mesh-shaped captures need
``MAX_DEVICES`` XLA host devices — ``tools/run_check.py`` forces the
platform device count before jax initializes.
"""
from __future__ import annotations

from typing import Any

from ..report import FAIL, CheckReport, Finding, TracedRecord
from . import collectives, dtype_flow, hygiene
from .base import (
    COLL_FAMILY,
    DTYPE_FAMILY,
    HYG_FAMILY,
    TRACED_FAMILIES,
    TRACED_RULES,
    fail_rules,
    rules_for,
)
from .capture import (
    CollectiveFootprint,
    TracedProgram,
    capture_checkpoint_encode,
    capture_gf_pallas,
    capture_gf_ref,
    capture_serve_decode,
    capture_serve_prefill,
    capture_spmd_repair,
    capture_train_step,
    iter_eqns,
    require_devices,
)


def spmd_shapes() -> list[tuple[str, int, int, int]]:
    """Every REGISTRY_SWEEP DRC shape, plus RS(9,6,3) as the
    non-layered contrast — the shapes whose compiled HLO byte
    accounting the gate demands."""
    from ..plan import REGISTRY_SWEEP

    shapes: list[tuple[str, int, int, int]] = []
    for family in ("DRC-f1", "DRC-f2"):
        for cfg in REGISTRY_SWEEP[family]:
            if cfg not in shapes:
                shapes.append(cfg)
    shapes.append(("RS", 9, 6, 3))
    return shapes


# devices the largest mesh-shaped capture needs: r*w == n, max n == 15
MAX_DEVICES = 16


def run_rules(program: TracedProgram) -> list[Finding]:
    """Run every registered traced rule over one captured program."""
    findings: list[Finding] = []
    for rid in sorted(TRACED_RULES):
        _, fn = TRACED_RULES[rid]
        findings.extend(fn(program))
    return findings


def _record(program: TracedProgram) -> TracedRecord:
    info: dict[str, Any] = {
        "eqns": sum(1 for _ in iter_eqns(program.jaxpr)),
        "permutes": len(program.footprint.permutes),
        "gathers": len(program.footprint.gathers),
        "lowered": bool(program.stablehlo),
        "rules_checked": len(TRACED_RULES),
    }
    spec = program.meta.get("spec")
    if spec is not None:
        info["cross_units"] = spec.cross_units
        from repro.launch.hlo_analysis import cross_pod_permute_bytes

        info["hlo_cross_bytes"] = cross_pod_permute_bytes(
            program.hlo, int(program.meta["w"])
        )
    return TracedRecord(
        label=program.name,
        kind=program.kind,
        findings=run_rules(program),
        info=info,
    )


def run_traced_sweep() -> list[TracedRecord]:
    """Capture + analyze every traced entry point; one record each."""
    records: list[TracedRecord] = []
    for fam, n, k, r in spmd_shapes():
        records.append(_record(capture_spmd_repair(fam, n, k, r)))
    records.append(_record(capture_gf_ref()))
    records.append(_record(capture_gf_pallas()))
    records.append(_record(capture_serve_prefill()))
    records.append(_record(capture_serve_decode()))
    records.append(_record(capture_train_step()))
    records.append(_record(capture_checkpoint_encode()))
    return records


def traced_report() -> CheckReport:
    """A CheckReport holding only the traced sweep."""
    return CheckReport(traced_records=run_traced_sweep())


# --------------------------------------------------------------- self-test
# mutation name -> (family, owning rule id)
TRACED_MUTATIONS: dict[str, tuple[str, str]] = {
    **{m: (DTYPE_FAMILY, r) for m, r in dtype_flow.DTYPE_MUTATIONS.items()},
    **{m: (COLL_FAMILY, r) for m, r in collectives.COLL_MUTATIONS.items()},
    **{m: (HYG_FAMILY, r) for m, r in hygiene.HYG_MUTATIONS.items()},
}

_BASE_SHAPE = ("DRC", 6, 4, 3)
_base_cache: list[TracedProgram] = []


def _base_repair_program() -> TracedProgram:
    """One captured known-good repair artifact, shared by the artifact
    mutations (needs n=6 host devices)."""
    if not _base_cache:
        _base_cache.append(capture_spmd_repair(*_BASE_SHAPE))
    return _base_cache[0]


def mutant_program(mutation: str) -> TracedProgram:
    """The corrupted program for one named mutation."""
    if mutation in dtype_flow.DTYPE_MUTATIONS:
        return dtype_flow.dtype_mutation_program(mutation)
    if mutation in collectives.COLL_MUTATIONS:
        return collectives.coll_mutation_program(
            mutation, _base_repair_program()
        )
    if mutation == "hyg_callback":
        return hygiene.callback_mutation_program()
    if mutation == "hyg_no_donation":
        return hygiene.donation_mutation_program(_base_repair_program())
    raise ValueError(f"unknown traced mutation {mutation!r}")


def self_test_traced() -> list[tuple[str, str, bool, bool]]:
    """Corrupt one captured artifact per mutation.

    Returns (mutation, owning rule, caught, exclusive) rows; the gate
    demands caught AND exclusive — every registered traced rule runs
    over the corrupted program and the corruption must FAIL exactly the
    rule that owns it.
    """
    rows: list[tuple[str, str, bool, bool]] = []
    for mutation, (_family, owner) in TRACED_MUTATIONS.items():
        fails = fail_rules(run_rules(mutant_program(mutation)))
        rows.append((mutation, owner, owner in fails, fails == {owner}))
    return rows


__all__ = [
    "COLL_FAMILY", "DTYPE_FAMILY", "HYG_FAMILY", "MAX_DEVICES",
    "TRACED_FAMILIES", "TRACED_MUTATIONS", "TRACED_RULES",
    "CollectiveFootprint", "TracedProgram", "TracedRecord",
    "capture_checkpoint_encode", "capture_gf_pallas", "capture_gf_ref",
    "capture_serve_decode", "capture_serve_prefill",
    "capture_spmd_repair", "capture_train_step", "collectives",
    "dtype_flow", "fail_rules", "hygiene", "iter_eqns", "mutant_program",
    "require_devices", "rules_for", "run_rules", "run_traced_sweep",
    "self_test_traced", "spmd_shapes", "traced_report", "FAIL", "Finding",
]
