"""Rule registry shared by the lowered-layer analyzers.

Mirrors ``repro.check.plan``'s registry, but lowered rules are grouped
by *family* because each family analyzes a different artifact type:

* ``spmd-schedule`` — a ``SpmdRepairSpec`` (plus the code/plan it was
  lowered from),
* ``shard-rules`` — a sharding ``Rules`` table resolved against a
  model config on concrete meshes,
* ``pallas-kernel`` — a ``KernelGeometry`` or a kernel source file.

``rule(rule_id, family)`` registers a rule under a stable id; the
sweep, the mutation self-test and the docs catalog all read
``LOWERED_RULES``.  Ids are namespaced ``lowered.<family>.<name>``.
"""
from __future__ import annotations

from typing import Any, Callable, TypeVar

from ..report import Finding

LoweredRuleFn = Callable[..., list[Finding]]
_F = TypeVar("_F", bound=LoweredRuleFn)

SPMD_FAMILY = "spmd-schedule"
SHARD_FAMILY = "shard-rules"
PALLAS_FAMILY = "pallas-kernel"

LOWERED_FAMILIES = (SPMD_FAMILY, SHARD_FAMILY, PALLAS_FAMILY)

# rule id -> (family, rule fn); populated by the family modules at import
LOWERED_RULES: dict[str, tuple[str, LoweredRuleFn]] = {}


def rule(rule_id: str, family: str) -> Callable[[_F], _F]:
    """Register a lowered-layer rule under a stable id."""
    if family not in LOWERED_FAMILIES:
        raise ValueError(f"unknown lowered family {family!r}")

    def deco(fn: _F) -> _F:
        if rule_id in LOWERED_RULES:
            raise ValueError(f"duplicate lowered rule id {rule_id!r}")
        LOWERED_RULES[rule_id] = (family, fn)
        return fn

    return deco


def rules_for(family: str) -> dict[str, LoweredRuleFn]:
    """The registered rules of one family, id -> fn."""
    return {
        rid: fn for rid, (fam, fn) in LOWERED_RULES.items() if fam == family
    }


def fail_rules(findings: list[Finding]) -> set[str]:
    """Distinct rule ids that FAILed — the mutation self-test's currency."""
    from ..report import FAIL

    return {f.rule for f in findings if f.severity == FAIL}


def as_witness(**kw: Any) -> dict[str, Any]:
    """Tiny helper keeping witness construction one line at call sites."""
    return kw
