"""Static consistency of the sharding-rule tables (dist.sharding).

``resolve_spec`` promises two things at runtime — divisibility fallback
and no double mesh-axis use.  These rules prove the *tables* (and the
resolver as deployed) keep those promises for every model config before
anything is compiled:

* ``lowered.shard.axis-reuse`` — no rule entry lists the same mesh axis
  twice for one logical dimension, and every listed axis is a known
  mesh axis (``data``/``model``/``pod``).  A duplicated candidate would
  make the resolver's first-come-first-served scan order-dependent.
* ``lowered.shard.divisibility`` — resolving every representative
  weight/activation shape of a config against concrete meshes never
  raises, never shards a dimension unevenly, never invents an axis the
  table does not allow, never uses one mesh axis for two dimensions of
  a spec, and the replication fallback is reachable (a prime-sized
  dimension must resolve to replicated, not an XLA reshape error).
* ``lowered.shard.multi-pod`` — the ``pod`` mesh axis appears only as
  the *leading* batch candidate of a ``multi_pod`` table (data
  parallelism across pods, the paper's rack analogue); a weight axis
  sharded over ``pod`` would silently turn the repair mesh's pod
  dimension into tensor parallelism.  The table must also compose with
  the (pod, node) repair mesh: resolution succeeds and no non-batch
  dimension touches ``pod``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

from repro.models.common import LOGICAL

from ..report import FAIL, Finding, LoweredRecord
from .base import SHARD_FAMILY, rule

R_SH_REUSE = "lowered.shard.axis-reuse"
R_SH_DIV = "lowered.shard.divisibility"
R_SH_POD = "lowered.shard.multi-pod"

KNOWN_MESH_AXES = ("data", "model", "pod")

# A dimension size no mesh axis divides: the replication fallback must
# absorb it.  7919 is prime and larger than any realistic axis size.
_PRIME_DIM = 7919

# canonical meshes the sweep resolves against (axis name -> size)
CANONICAL_MESHES: tuple[dict[str, int], ...] = (
    {"data": 2, "model": 4},
    {"data": 4, "model": 2},
)
MULTI_POD_MESHES: tuple[dict[str, int], ...] = (
    {"pod": 3, "data": 2, "model": 2},
    {"pod": 3, "node": 2},  # the repair mesh of repro.dist.collectives
)


class TableMesh:
    """Minimal mesh stand-in: resolve_spec only reads ``.shape``."""

    def __init__(self, shape: Mapping[str, int]) -> None:
        self.shape = dict(shape)

    def __repr__(self) -> str:
        return f"TableMesh({self.shape})"


@dataclasses.dataclass(frozen=True)
class ShardArtifact:
    """One (rule table, model config) pair plus the resolver to vet.

    ``resolver`` is part of the artifact on purpose: the guarantee under
    test lives in ``resolve_spec`` as deployed, so a resolver swap (see
    the ``shard_greedy_resolver`` mutation) is a lowering defect too.
    """

    rules: Any  # repro.dist.sharding.Rules
    config: Any  # repro.configs.models.config.ArchConfig
    meshes: tuple[Mapping[str, int], ...]
    resolver: Callable[..., Any]

    def label(self) -> str:
        return f"{self.rules!r} x {self.config.name}"


def _representative_shapes(
    config: Any, *, batch: int = 8, seq: int = 128
) -> list[tuple[tuple[str, ...], tuple[int, ...]]]:
    """Logical-axis tuples + concrete shapes covering every weight and
    activation family the models actually resolve."""
    return [
        (("batch", "seq", "embed"), (batch, seq, config.d_model)),
        (("embed", "ffn"), (config.d_model, config.d_ff)),
        (("embed", "heads"), (config.d_model, max(config.n_heads, 1))),
        (("embed", "kv"), (config.d_model, max(config.n_kv_heads, 1))),
        (("embed", "vocab"), (config.d_model, config.padded_vocab)),
    ]


@rule(R_SH_REUSE, SHARD_FAMILY)
def check_axis_reuse(art: ShardArtifact) -> list[Finding]:
    """Rule-table hygiene: unique, known mesh axes per logical axis."""
    out: list[Finding] = []
    for name in LOGICAL:
        candidates = art.rules.mesh_axes(name)
        seen: set[str] = set()
        for axis in candidates:
            if axis in seen:
                out.append(Finding(
                    R_SH_REUSE, FAIL,
                    f"{art.rules!r}: logical axis {name!r} lists mesh axis "
                    f"{axis!r} twice ({candidates}) — the resolver's "
                    f"first-come-first-served scan becomes order-dependent",
                    {"logical": name, "axis": axis,
                     "candidates": list(candidates)},
                ))
            seen.add(axis)
            if axis not in KNOWN_MESH_AXES:
                out.append(Finding(
                    R_SH_REUSE, FAIL,
                    f"{art.rules!r}: logical axis {name!r} maps to unknown "
                    f"mesh axis {axis!r} (known: {KNOWN_MESH_AXES})",
                    {"logical": name, "axis": axis},
                ))
    return out


def _spec_entries(spec: Any) -> list[tuple[str, ...]]:
    """PartitionSpec entries normalized to tuples of mesh-axis names."""
    out: list[tuple[str, ...]] = []
    for entry in spec:
        if entry is None:
            out.append(())
        elif isinstance(entry, str):
            out.append((entry,))
        else:
            out.append(tuple(entry))
    return out


@rule(R_SH_DIV, SHARD_FAMILY)
def check_divisibility(art: ShardArtifact) -> list[Finding]:
    """The resolver keeps its divisibility/no-double-use guarantees for
    every representative shape of the config on every mesh."""
    out: list[Finding] = []
    shapes = _representative_shapes(art.config)
    for mesh_shape in art.meshes:
        mesh = TableMesh(mesh_shape)
        for names, shape in shapes:
            try:
                spec = art.resolver(names, shape, mesh, art.rules)
            except Exception as e:
                out.append(Finding(
                    R_SH_DIV, FAIL,
                    f"{art.label()}: resolver raised {type(e).__name__} for "
                    f"{names} x {shape} on {mesh_shape}: {e}",
                    {"names": list(names), "shape": list(shape),
                     "mesh": dict(mesh_shape)},
                ))
                continue
            entries = _spec_entries(spec)
            if len(entries) != len(shape):
                out.append(Finding(
                    R_SH_DIV, FAIL,
                    f"{art.label()}: spec rank {len(entries)} != shape rank "
                    f"{len(shape)} for {names}",
                    {"names": list(names), "entries": entries},
                ))
                continue
            used: list[str] = []
            for name, dim, axes in zip(names, shape, entries):
                allowed = art.rules.mesh_axes(name)
                product = 1
                for axis in axes:
                    product *= mesh_shape.get(axis, 1)
                    if axis not in allowed:
                        out.append(Finding(
                            R_SH_DIV, FAIL,
                            f"{art.label()}: resolver shards {name!r} over "
                            f"{axis!r}, which the rule table does not allow "
                            f"({allowed})",
                            {"logical": name, "axis": axis,
                             "allowed": list(allowed)},
                        ))
                    if axis in used:
                        out.append(Finding(
                            R_SH_DIV, FAIL,
                            f"{art.label()}: mesh axis {axis!r} used by two "
                            f"dimensions of one spec ({names} x {shape})",
                            {"axis": axis, "names": list(names)},
                        ))
                    used.append(axis)
                if product > 1 and dim % product != 0:
                    out.append(Finding(
                        R_SH_DIV, FAIL,
                        f"{art.label()}: dimension {name!r}={dim} sharded "
                        f"over {axes} (product {product}) does not divide "
                        f"evenly on {mesh_shape} — runtime would reshape-"
                        f"error or silently pad",
                        {"logical": name, "dim": dim, "axes": list(axes),
                         "product": product, "mesh": dict(mesh_shape)},
                    ))
        # fallback reachability: a prime dimension must replicate
        for name in ("ffn", "embed", "vocab"):
            try:
                spec = art.resolver((name,), (_PRIME_DIM,), mesh, art.rules)
            except Exception as e:
                out.append(Finding(
                    R_SH_DIV, FAIL,
                    f"{art.label()}: prime-dimension probe raised "
                    f"{type(e).__name__}: {e}",
                    {"logical": name, "mesh": dict(mesh_shape)},
                ))
                continue
            entries = _spec_entries(spec)
            if entries and entries[0]:
                out.append(Finding(
                    R_SH_DIV, FAIL,
                    f"{art.label()}: replication fallback unreachable — "
                    f"prime dimension {name!r}={_PRIME_DIM} resolved to "
                    f"{entries[0]} instead of replicated on {mesh_shape}",
                    {"logical": name, "entries": entries[0],
                     "mesh": dict(mesh_shape)},
                ))
    return out


@rule(R_SH_POD, SHARD_FAMILY)
def check_multi_pod(art: ShardArtifact) -> list[Finding]:
    """``pod`` only ever data-shards batch, and the table composes with
    the (pod, node) repair mesh."""
    out: list[Finding] = []
    rules = art.rules
    batch = rules.mesh_axes("batch")
    if rules.multi_pod and (not batch or batch[0] != "pod"):
        out.append(Finding(
            R_SH_POD, FAIL,
            f"{rules!r}: multi_pod table's batch rule {batch} does not "
            f"lead with 'pod' — cross-pod data parallelism is lost",
            {"batch": list(batch)},
        ))
    for name in LOGICAL:
        if name == "batch":
            continue
        candidates = rules.mesh_axes(name)
        if "pod" in candidates:
            out.append(Finding(
                R_SH_POD, FAIL,
                f"{rules!r}: logical axis {name!r} lists the 'pod' mesh "
                f"axis ({candidates}) — a weight sharded across pods "
                f"turns the repair mesh's pod dimension into tensor "
                f"parallelism and every repair into a cross-pod gather",
                {"logical": name, "candidates": list(candidates)},
            ))
    if not rules.multi_pod and "pod" in batch:
        out.append(Finding(
            R_SH_POD, FAIL,
            f"{rules!r}: single-pod table shards batch over 'pod' "
            f"({batch})",
            {"batch": list(batch)},
        ))
    if rules.multi_pod:
        repair_mesh = TableMesh({"pod": 3, "node": 2})
        names = ("batch", "seq", "embed")
        shape = (12, 128, art.config.d_model)
        try:
            spec = art.resolver(names, shape, repair_mesh, rules)
        except Exception as e:
            out.append(Finding(
                R_SH_POD, FAIL,
                f"{art.label()}: resolution on the (pod, node) repair "
                f"mesh raised {type(e).__name__}: {e}",
                {"names": list(names), "shape": list(shape)},
            ))
            return out
        entries = _spec_entries(spec)
        for name, axes in zip(names[1:], entries[1:]):
            if "pod" in axes:
                out.append(Finding(
                    R_SH_POD, FAIL,
                    f"{art.label()}: non-batch dimension {name!r} resolved "
                    f"over 'pod' on the repair mesh ({axes})",
                    {"logical": name, "axes": list(axes)},
                ))
    return out


SHARD_RULES_ = (check_axis_reuse, check_divisibility, check_multi_pod)


def analyze_shard_artifact(art: ShardArtifact) -> list[Finding]:
    findings: list[Finding] = []
    for fn in SHARD_RULES_:
        findings.extend(fn(art))
    return findings


# --------------------------------------------------------------------------
# Sweep entry point
# --------------------------------------------------------------------------


def verify_shard_rules(
    config: Any, mode: str, *, family: str = SHARD_FAMILY
) -> LoweredRecord:
    """Analyze one (config, mode) pair — both single- and multi-pod
    tables — against the canonical meshes."""
    from repro.dist.sharding import make_rules, resolve_spec

    findings: list[Finding] = []
    for multi_pod, meshes in (
        (False, CANONICAL_MESHES),
        (True, (*MULTI_POD_MESHES, *CANONICAL_MESHES)),
    ):
        art = ShardArtifact(
            rules=make_rules(mode, multi_pod=multi_pod),
            config=config,
            meshes=tuple(meshes),
            resolver=resolve_spec,
        )
        findings.extend(analyze_shard_artifact(art))
    return LoweredRecord(
        label=f"{config.name}/{mode}",
        family=family,
        artifact=f"Rules({mode!r}) x {config.name}",
        findings=findings,
        info={
            "meshes": [dict(m) for m in CANONICAL_MESHES + MULTI_POD_MESHES],
            "shapes": len(_representative_shapes(config)),
            "rules_checked": len(SHARD_RULES_),
        },
    )


# --------------------------------------------------------------------------
# Mutations
# --------------------------------------------------------------------------

SHARD_MUTATIONS: dict[str, str] = {
    "shard_double_map": R_SH_REUSE,
    "shard_greedy_resolver": R_SH_DIV,
    "shard_pod_leak": R_SH_POD,
}


class _MutantRules:
    """Rules stand-in with one table entry overridden."""

    def __init__(self, base: Any, override: dict[str, tuple[str, ...]]):
        self.mode = base.mode
        self.multi_pod = base.multi_pod
        self._base = base
        self._override = override

    def mesh_axes(self, name: str) -> tuple[str, ...]:
        if name in self._override:
            return self._override[name]
        axes = self._base.mesh_axes(name)
        return tuple(axes)

    def __repr__(self) -> str:
        return f"Mutant({self._base!r}, {self._override})"


def _greedy_resolver(
    names: Any, shape: Any, mesh: Any, rules: Any = None
) -> Any:
    """A deliberately broken resolver: respects the rule table and the
    no-double-use scan but skips the divisibility test."""
    import jax

    from repro.dist.sharding import current_rules

    rules = current_rules() if rules is None else rules
    mesh_shape = dict(mesh.shape)
    used: set[str] = set()
    entries: list[Any] = []
    for name, _dim in zip(names, shape):
        if name is None:
            entries.append(None)
            continue
        chosen = [
            axis for axis in rules.mesh_axes(name)
            if mesh_shape.get(axis, 0) > 1 and axis not in used
        ]
        used.update(chosen)
        if not chosen:
            entries.append(None)
        elif len(chosen) == 1:
            entries.append(chosen[0])
        else:
            entries.append(tuple(chosen))
    return jax.sharding.PartitionSpec(*entries)


def mutate_shard(art: ShardArtifact, mutation: str) -> ShardArtifact:
    """Return a corrupted copy of the artifact."""
    if mutation == "shard_double_map":
        # On 'expert' no representative shape resolves, so only the
        # static table rule can catch this — which is the point: the
        # resolver would happily shard one dim over model twice
        # (product model^2) the day an expert-parallel config lands.
        bad = _MutantRules(art.rules, {"expert": ("model", "model")})
        return dataclasses.replace(art, rules=bad)
    if mutation == "shard_greedy_resolver":
        return dataclasses.replace(art, resolver=_greedy_resolver)
    if mutation == "shard_pod_leak":
        from repro.dist.sharding import make_rules

        base = make_rules(art.rules.mode, multi_pod=True)
        bad = _MutantRules(base, {"embed": ("pod",)})
        return dataclasses.replace(art, rules=bad)
    raise ValueError(f"unknown shard mutation {mutation!r}")


__all__ = [
    "R_SH_REUSE", "R_SH_DIV", "R_SH_POD", "SHARD_MUTATIONS",
    "CANONICAL_MESHES", "MULTI_POD_MESHES", "ShardArtifact", "TableMesh",
    "analyze_shard_artifact", "verify_shard_rules", "mutate_shard",
]
