"""Static analysis of the SPMD collective-permute schedule.

``plan_to_spmd`` freezes a ``RepairPlan`` into a ``SpmdRepairSpec`` —
stacked encode matrices, per-pod cross-ship row lists, a decode gather
order.  The plan verifier proves the *plan* optimal; these rules prove
the *lowering* did not lose that optimality on the way to hardware:

* ``lowered.spmd.permute-partial`` — the declared collective-permute
  steps form a valid partial permutation: no pod ships to itself, no
  duplicate source or destination within the schedule, every step lands
  on the collector pod.  A self-send or duplicate source would make the
  compiled ``ppermute`` drop or double-deliver units silently.
* ``lowered.spmd.rows-live`` — every scheduled pool row exists (in
  bounds), is shipped at most once per pod, and points at a unit the
  shipping pod actually *produces* (never into the zero padding the
  stacked matrices carry).  Shipping a padding row is the lowered
  analogue of a dangling DAG edge.
* ``lowered.spmd.dead-device`` — the failed device contributes nothing:
  its NodeEncode/RelayerEncode rows are all-zero, no device encodes
  units the plan never routes (ghost encodes), and the relayer set of
  the lowering equals the plan's relayers exactly.
* ``lowered.spmd.decode-gather`` — the collector's gather order is
  consistent: one decode column per gathered unit, all indices in
  bounds of the post-permute pool, every received unit consumed at most
  once, and local references resolve to live target-pod rows.
* ``lowered.spmd.byte-accounting`` — per-pod scheduled cross units
  equal the plan's per-rack cross accounting and the totals equal
  ``traffic_blocks()`` (blocks x alpha) for both scopes; the Eq. (3)
  bound survives lowering pod by pod, not just in aggregate.
* ``lowered.spmd.rotation-balance`` — across a full rotation cycle of
  ``spmd_node_recovery`` stripes, relayer duty within each remote pod
  is balanced within one stripe (paper §5.2 load balancing).

Ownership note: rows scheduled *by the target pod itself* are reported
only by ``permute-partial`` (self-send); the other rules skip that slot
so each defect has exactly one owning rule — the property the mutation
self-test asserts.
"""
from __future__ import annotations

from typing import Any, Iterable

import numpy as np

from repro.core.code_base import ErasureCode
from repro.core.repair import TARGET, RepairPlan

from ..report import FAIL, Finding, LoweredRecord
from .base import SPMD_FAMILY, fail_rules, rule

R_LS_PERMUTE = "lowered.spmd.permute-partial"
R_LS_ROWS = "lowered.spmd.rows-live"
R_LS_DEAD = "lowered.spmd.dead-device"
R_LS_GATHER = "lowered.spmd.decode-gather"
R_LS_BYTES = "lowered.spmd.byte-accounting"
R_LS_ROTATION = "lowered.spmd.rotation-balance"


# --------------------------------------------------------------------------
# Shared derivations from the plan (the ground truth the spec must match)
# --------------------------------------------------------------------------


def _node_units(plan: RepairPlan) -> dict[int, int]:
    """Units each node's stacked NodeEncode block really produces."""
    out: dict[int, int] = {}
    for s in plan.node_sends:
        out[s.src] = out.get(s.src, 0) + s.units
    return out


def _relayer_units(plan: RepairPlan) -> dict[int, int]:
    out: dict[int, int] = {}
    for s in plan.relayer_sends:
        out[s.src] = out.get(s.src, 0) + s.units
    return out


def _live_row(
    plan: RepairPlan, spec: Any, pod: int, row: int
) -> tuple[bool, str]:
    """Is pool row `row` of pod `pod` a unit that pod really produces?

    Returns (live, reason-if-not).  Row layout mirrors plan_to_spmd:
    rows [0, w*nu) are node units (slot-major, nu-strided), rows
    [w*nu, w*nu + w*ru) are relayer units.
    """
    w, nu, ru = spec.w, spec.nu, spec.ru
    if not 0 <= row < spec.pool_rows:
        return False, f"row {row} out of bounds [0, {spec.pool_rows})"
    if row < w * nu:
        slot, off = divmod(row, nu)
        node = pod * w + slot
        have = _node_units(plan).get(node, 0)
        if off >= have:
            return False, (
                f"row {row} is zero padding: node {node} produces {have} "
                f"unit(s), offset {off} requested"
            )
    else:
        slot, off = divmod(row - w * nu, ru)
        node = pod * w + slot
        have = _relayer_units(plan).get(node, 0)
        if off >= have:
            return False, (
                f"row {row} is zero padding: relayer {node} produces "
                f"{have} unit(s), offset {off} requested"
            )
    return True, ""


def _cross_units_by_pod(plan: RepairPlan) -> dict[int, int]:
    """Cross-rack units each non-target rack ships, from the plan's own
    sends with the same classification rule as ``traffic_blocks``."""
    rack = plan.placement.rack_of
    target_rack = rack(plan.failed)
    want: dict[int, int] = {}
    for s in plan.node_sends:
        if s.dst == TARGET and rack(s.src) != target_rack:
            want[rack(s.src)] = want.get(rack(s.src), 0) + s.units
    for s in plan.relayer_sends:
        if rack(s.src) != target_rack:
            want[rack(s.src)] = want.get(rack(s.src), 0) + s.units
    return want


def _nontarget_steps(spec: Any) -> list[tuple[int, tuple[int, ...]]]:
    return [
        (q, rows) for q, dst, rows in spec.permute_steps()
        if q != spec.target_pod
    ]


# --------------------------------------------------------------------------
# Per-spec rules
# --------------------------------------------------------------------------


@rule(R_LS_PERMUTE, SPMD_FAMILY)
def check_permute_partial(
    code: ErasureCode, plan: RepairPlan, spec: Any
) -> list[Finding]:
    """Declared permute steps form a valid partial permutation."""
    out: list[Finding] = []
    seen_src: set[int] = set()
    for src, dst, rows in spec.permute_steps():
        if src == dst:
            out.append(Finding(
                R_LS_PERMUTE, FAIL,
                f"pod {src} ships {len(rows)} unit(s) to itself — a "
                f"self-send collective-permute delivers nothing",
                {"pod": src, "rows": list(rows)},
            ))
            continue
        if not 0 <= src < spec.r:
            out.append(Finding(
                R_LS_PERMUTE, FAIL,
                f"permute step from pod {src} outside mesh [0, {spec.r})",
                {"pod": src, "r": spec.r},
            ))
        if dst != spec.target_pod:
            out.append(Finding(
                R_LS_PERMUTE, FAIL,
                f"permute step {src}->{dst} does not land on the "
                f"collector pod {spec.target_pod}",
                {"src": src, "dst": dst, "target_pod": spec.target_pod},
            ))
        if src in seen_src:
            out.append(Finding(
                R_LS_PERMUTE, FAIL,
                f"pod {src} appears twice as a permute source — the "
                f"second step would overwrite the first's delivery",
                {"pod": src},
            ))
        seen_src.add(src)
    return out


@rule(R_LS_ROWS, SPMD_FAMILY)
def check_rows_live(
    code: ErasureCode, plan: RepairPlan, spec: Any
) -> list[Finding]:
    """Every scheduled row is in bounds, unique per pod, and live."""
    out: list[Finding] = []
    for q, rows in _nontarget_steps(spec):
        seen: set[int] = set()
        for row in rows:
            if row in seen:
                out.append(Finding(
                    R_LS_ROWS, FAIL,
                    f"pod {q} ships pool row {row} twice",
                    {"pod": q, "row": row},
                ))
                continue
            seen.add(row)
            live, why = _live_row(plan, spec, q, row)
            if not live:
                out.append(Finding(
                    R_LS_ROWS, FAIL, f"pod {q}: {why}",
                    {"pod": q, "row": row},
                ))
    return out


@rule(R_LS_DEAD, SPMD_FAMILY)
def check_dead_device(
    code: ErasureCode, plan: RepairPlan, spec: Any
) -> list[Finding]:
    """The failed device is dead and no device ghost-encodes."""
    out: list[Finding] = []
    node_senders = {s.src for s in plan.node_sends}
    for v in range(spec.n):
        if np.any(spec.node_mats[v]) and v not in node_senders:
            what = "the failed device" if v == plan.failed else f"device {v}"
            out.append(Finding(
                R_LS_DEAD, FAIL,
                f"{what} has a nonzero NodeEncode block but the plan "
                f"routes no send from it — a ghost encode would read "
                f"{'a dead' if v == plan.failed else 'an unscheduled'} "
                f"payload",
                {"device": v, "failed": plan.failed},
            ))
        if spec.ru and np.any(spec.relayer_mats[v]) and v not in set(
            plan.relayers
        ):
            out.append(Finding(
                R_LS_DEAD, FAIL,
                f"device {v} has a nonzero RelayerEncode block but is "
                f"not a plan relayer",
                {"device": v, "relayers": plan.relayers},
            ))
    if sorted(spec.rel_idx.tolist()) != plan.relayers:
        out.append(Finding(
            R_LS_DEAD, FAIL,
            f"spec relayer set {sorted(spec.rel_idx.tolist())} != plan "
            f"relayers {plan.relayers}",
            {"spec": sorted(spec.rel_idx.tolist()), "plan": plan.relayers},
        ))
    return out


@rule(R_LS_GATHER, SPMD_FAMILY)
def check_decode_gather(
    code: ErasureCode, plan: RepairPlan, spec: Any
) -> list[Finding]:
    """The collector's gather indices are consistent with the pool."""
    out: list[Finding] = []
    pool_rows = spec.pool_rows
    received = sum(len(rows) for _, rows in _nontarget_steps(spec))
    hi = pool_rows + received
    if len(spec.target_idx) != spec.decode.shape[1]:
        out.append(Finding(
            R_LS_GATHER, FAIL,
            f"gather order has {len(spec.target_idx)} entries but the "
            f"decode matrix consumes {spec.decode.shape[1]} units",
            {"gather": len(spec.target_idx), "decode": spec.decode.shape[1]},
        ))
    seen_recv: set[int] = set()
    for idx in spec.target_idx:
        if not 0 <= idx < hi:
            out.append(Finding(
                R_LS_GATHER, FAIL,
                f"gather index {idx} out of bounds [0, {hi}) "
                f"(pool {pool_rows} + received {received})",
                {"index": idx, "hi": hi},
            ))
            continue
        if idx >= pool_rows:
            if idx in seen_recv:
                out.append(Finding(
                    R_LS_GATHER, FAIL,
                    f"received unit at row {idx} consumed twice by the "
                    f"decode gather — one shipped unit is lost",
                    {"index": idx},
                ))
            seen_recv.add(idx)
        else:
            live, why = _live_row(plan, spec, spec.target_pod, idx)
            if not live:
                out.append(Finding(
                    R_LS_GATHER, FAIL,
                    f"local gather reference in target pod "
                    f"{spec.target_pod}: {why}",
                    {"index": idx, "target_pod": spec.target_pod},
                ))
    return out


@rule(R_LS_BYTES, SPMD_FAMILY)
def check_byte_accounting(
    code: ErasureCode, plan: RepairPlan, spec: Any
) -> list[Finding]:
    """Per-pod and total scheduled bytes match the plan exactly."""
    out: list[Finding] = []
    t = plan.traffic_blocks()
    want_by_pod = _cross_units_by_pod(plan)
    got_by_pod = {q: len(rows) for q, rows in _nontarget_steps(spec)}
    for q in range(spec.r):
        if q == spec.target_pod:
            continue
        want, got = want_by_pod.get(q, 0), got_by_pod.get(q, 0)
        if want != got:
            out.append(Finding(
                R_LS_BYTES, FAIL,
                f"pod {q} schedules {got} cross unit(s) but the plan "
                f"accounts {want}",
                {"pod": q, "scheduled": got, "planned": want},
            ))
    total_want = round(float(t["cross_rack_blocks"]) * plan.alpha)
    total_got = sum(got_by_pod.values())
    if total_got != total_want:
        out.append(Finding(
            R_LS_BYTES, FAIL,
            f"schedule ships {total_got} cross unit(s) total, plan "
            f"accounts {total_want} (blocks x alpha)",
            {"scheduled": total_got, "planned": total_want},
        ))
    inner_want = round(float(t["inner_rack_blocks"]) * plan.alpha)
    if spec.inner_units != inner_want:
        out.append(Finding(
            R_LS_BYTES, FAIL,
            f"schedule books {spec.inner_units} inner-rack unit(s), plan "
            f"accounts {inner_want}",
            {"scheduled": spec.inner_units, "planned": inner_want},
        ))
    return out


SPEC_RULES = (
    check_permute_partial,
    check_rows_live,
    check_dead_device,
    check_decode_gather,
    check_byte_accounting,
)


def analyze_spmd_spec(
    code: ErasureCode, plan: RepairPlan, spec: Any
) -> list[Finding]:
    """Run every per-spec schedule rule over one lowered plan."""
    findings: list[Finding] = []
    for fn in SPEC_RULES:
        findings.extend(fn(code, plan, spec))
    return findings


# --------------------------------------------------------------------------
# Rotation balance (a property of a *set* of stripe specs)
# --------------------------------------------------------------------------


@rule(R_LS_ROTATION, SPMD_FAMILY)
def check_rotation_balance(
    code: ErasureCode, failed: int, specs: list[Any]
) -> list[Finding]:
    """Relayer duty balanced within one stripe inside each remote pod."""
    out: list[Finding] = []
    if not specs:
        return out
    w = specs[0].w
    loads: dict[int, dict[int, int]] = {}
    for spec in specs:
        for v in spec.rel_idx.tolist():
            pod = int(v) // w
            loads.setdefault(pod, {})
            loads[pod][int(v)] = loads[pod].get(int(v), 0) + 1
    for pod, per in sorted(loads.items()):
        counts = {u: per.get(u, 0) for u in range(pod * w, (pod + 1) * w)}
        lo, hi = min(counts.values()), max(counts.values())
        if hi - lo > 1:
            out.append(Finding(
                R_LS_ROTATION, FAIL,
                f"relayer duty in pod {pod} unbalanced over "
                f"{len(specs)} stripe(s): {counts} (max-min = {hi - lo})",
                {"pod": pod, "loads": {str(u): c for u, c in counts.items()},
                 "stripes": len(specs), "failed": failed},
            ))
    return out


def rotation_specs(code: ErasureCode, failed: int) -> list[Any]:
    """One spec per stripe of a full rotation cycle (S = nodes/rack)."""
    from repro.dist.collectives import plan_to_spmd

    w = code.placement.nodes_per_rack
    return [
        plan_to_spmd(code, code.repair_plan(failed, rotation=s))
        for s in range(w)
    ]


def analyze_rotation(
    code: ErasureCode, failed: int, specs: list[Any]
) -> list[Finding]:
    return check_rotation_balance(code, failed, specs)


# --------------------------------------------------------------------------
# Sweep entry point
# --------------------------------------------------------------------------


def verify_spmd_lowering(
    code: ErasureCode,
    *,
    family: str = SPMD_FAMILY,
    failed_nodes: Iterable[int] | None = None,
) -> list[LoweredRecord]:
    """Lower and analyze every failed node's schedule, plus one
    rotation-balance record covering a full stripe cycle per node."""
    from repro.dist.collectives import plan_to_spmd

    records: list[LoweredRecord] = []
    nodes = list(range(code.n) if failed_nodes is None else failed_nodes)
    for f in nodes:
        try:
            plan = code.repair_plan(f)
            spec = plan_to_spmd(code, plan)
        except Exception as e:  # lowering itself must not blow up
            records.append(LoweredRecord(
                label=repr(code), family=family,
                artifact=f"SpmdRepairSpec(failed={f})",
                findings=[Finding(
                    "lowered.spmd.construction", FAIL,
                    f"plan_to_spmd({f}) raised {type(e).__name__}: {e}", {},
                )],
            ))
            continue
        records.append(LoweredRecord(
            label=repr(code), family=family,
            artifact=f"SpmdRepairSpec(failed={f})",
            findings=analyze_spmd_spec(code, plan, spec),
            info={
                "failed": f,
                "cross_units": spec.cross_units,
                "inner_units": spec.inner_units,
                "permute_steps": len(spec.permute_steps()),
                "rules_checked": len(SPEC_RULES),
            },
        ))
    rot_findings: list[Finding] = []
    rot_info: dict[str, Any] = {"stripes_per_node": {}}
    for f in nodes:
        specs = rotation_specs(code, f)
        rot_findings.extend(analyze_rotation(code, f, specs))
        rot_info["stripes_per_node"][str(f)] = len(specs)
    records.append(LoweredRecord(
        label=repr(code), family=family,
        artifact="rotation-cycle",
        findings=rot_findings, info=rot_info,
    ))
    return records


# --------------------------------------------------------------------------
# Mutations (each caught by exactly its owning rule — see self_test)
# --------------------------------------------------------------------------

SPMD_MUTATIONS: dict[str, str] = {
    "spmd_self_send": R_LS_PERMUTE,
    "spmd_oob_row": R_LS_ROWS,
    "spmd_ghost_failed": R_LS_DEAD,
    "spmd_gather_alias": R_LS_GATHER,
    "spmd_smuggle_unit": R_LS_BYTES,
    "spmd_stuck_rotation": R_LS_ROTATION,
}


def mutate_spmd(
    code: ErasureCode, plan: RepairPlan, spec: Any, mutation: str
) -> Any:
    """Return a deliberately corrupted copy of `spec` (or, for the
    rotation mutation, a corrupted stripe-spec list)."""
    import dataclasses

    if mutation == "spmd_self_send":
        # the target pod schedules a cross ship to itself
        cross = list(spec.cross_idx)
        cross[spec.target_pod] = (0,)
        return dataclasses.replace(spec, cross_idx=tuple(cross))
    if mutation == "spmd_oob_row":
        # one shipped row points past the pod's unit pool
        cross = list(spec.cross_idx)
        for q, rows in _nontarget_steps(spec):
            cross[q] = (spec.pool_rows + 7, *rows[1:])
            return dataclasses.replace(spec, cross_idx=tuple(cross))
        raise ValueError("no non-target pod ships units in this spec")
    if mutation == "spmd_ghost_failed":
        # the failed (dead) device suddenly encodes a unit
        mats = spec.node_mats.copy()
        mats[plan.failed, 0, 0] = 1
        return dataclasses.replace(spec, node_mats=mats)
    if mutation == "spmd_gather_alias":
        # the decode gather consumes one received unit twice
        idx = list(spec.target_idx)
        recv = [i for i, v in enumerate(idx) if v >= spec.pool_rows]
        if len(recv) < 2:
            raise ValueError("fewer than two received units to alias")
        idx[recv[1]] = idx[recv[0]]
        return dataclasses.replace(spec, target_idx=tuple(idx))
    if mutation == "spmd_smuggle_unit":
        # a pod ships one extra *live* unit the plan never routed cross
        units = _node_units(plan)
        cross = list(spec.cross_idx)
        for q, rows in _nontarget_steps(spec):
            scheduled = set(rows)
            for node, have in sorted(units.items()):
                if plan.placement.rack_of(node) != q:
                    continue
                for off in range(have):
                    row = (node % spec.w) * spec.nu + off
                    if row not in scheduled:
                        cross[q] = (*rows, row)
                        return dataclasses.replace(
                            spec, cross_idx=tuple(cross)
                        )
        raise ValueError("every live unit is already scheduled")
    if mutation == "spmd_stuck_rotation":
        # every stripe reuses rotation 0's relayers (no rotation at all)
        from repro.dist.collectives import plan_to_spmd

        w = code.placement.nodes_per_rack
        stuck = plan_to_spmd(code, code.repair_plan(plan.failed, rotation=0))
        return [stuck] * w
    raise ValueError(f"unknown spmd mutation {mutation!r}")


def spmd_mutation_findings(
    code: ErasureCode, plan: RepairPlan, mutated: Any
) -> list[Finding]:
    """Findings of the whole spmd family over a mutated artifact."""
    if isinstance(mutated, list):  # a stripe-spec set (rotation mutation)
        findings = analyze_rotation(code, plan.failed, mutated)
        for spec in mutated:
            findings.extend(analyze_spmd_spec(code, plan, spec))
        return findings
    return analyze_spmd_spec(code, plan, mutated) + analyze_rotation(
        code, plan.failed, [mutated]
    )


__all__ = [
    "R_LS_PERMUTE", "R_LS_ROWS", "R_LS_DEAD", "R_LS_GATHER", "R_LS_BYTES",
    "R_LS_ROTATION", "SPMD_MUTATIONS", "analyze_spmd_spec",
    "analyze_rotation", "rotation_specs", "verify_spmd_lowering",
    "mutate_spmd", "spmd_mutation_findings", "fail_rules",
]
