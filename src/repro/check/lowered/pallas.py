"""Abstract interpretation of Pallas kernel geometry + GF dtype safety.

The kernels in ``repro.kernels`` are correct today because their tests
compare against the log/exp oracle — in interpret mode, on small
shapes.  These rules prove the *geometry* (the part interpret mode does
not exercise faithfully: BlockSpec index maps over the real grid) and
the dtype discipline statically, for every registered shape:

* ``lowered.pallas.oob`` — every operand's index map is evaluated at
  every grid point; ``index * block_shape`` must stay inside the full
  array for each dimension.  Pallas silently clamps or wraps
  out-of-bounds blocks depending on backend — a wrong index map
  corrupts payloads without crashing.
* ``lowered.pallas.out-alias`` — the output index map must be injective
  across the grid: two grid steps writing the same output block is a
  write-write race whose winner depends on grid iteration order.
* ``lowered.pallas.gf-dtype`` — an AST pass over the kernel sources.
  GF(2^8) code lives in uint8; ``+``/``-``/``*`` on uint8 wraps mod 256
  silently (GF addition is XOR, not ``+``), reductions widen to the
  input dtype unless told otherwise, and an MXU matmul without
  ``preferred_element_type`` accumulates in the input dtype — for int8
  bitplanes that overflows at K >= 16.  The pass tracks uint8-ness
  through assignments, casts, shifts and masks, and flags arithmetic
  that could silently widen or wrap.

The geometry artifact is :class:`repro.kernels.gf_matmul.KernelGeometry`
— the same frozen object ``gf_matmul_pallas`` builds its BlockSpecs
from, so the verifier and the compiled kernel cannot drift apart.
"""
from __future__ import annotations

import ast
import dataclasses
import itertools
import math
from typing import Any, Iterable, Sequence

from ..report import FAIL, Finding, LoweredRecord
from .base import PALLAS_FAMILY, rule

R_PL_OOB = "lowered.pallas.oob"
R_PL_ALIAS = "lowered.pallas.out-alias"
R_PL_DTYPE = "lowered.pallas.gf-dtype"


# --------------------------------------------------------------------------
# Geometry rules (symbolic grid sweep)
# --------------------------------------------------------------------------


def _grid_points(grid: Sequence[int]) -> Iterable[tuple[int, ...]]:
    return itertools.product(*(range(g) for g in grid))


def _check_operand(
    geom: Any,
    what: str,
    shape: Sequence[int],
    block: Sequence[int],
    index_map: Any,
) -> list[Finding]:
    out: list[Finding] = []
    if len(shape) != len(block):
        out.append(Finding(
            R_PL_OOB, FAIL,
            f"{geom.name}/{what}: block rank {len(block)} != array rank "
            f"{len(shape)}",
            {"shape": list(shape), "block": list(block)},
        ))
        return out
    for point in _grid_points(geom.grid):
        try:
            idx = tuple(int(v) for v in index_map(*point))
        except Exception as e:
            out.append(Finding(
                R_PL_OOB, FAIL,
                f"{geom.name}/{what}: index map raised "
                f"{type(e).__name__} at grid point {point}: {e}",
                {"point": list(point)},
            ))
            return out
        if len(idx) != len(block):
            out.append(Finding(
                R_PL_OOB, FAIL,
                f"{geom.name}/{what}: index map returned {len(idx)} "
                f"indices for a rank-{len(block)} block at {point}",
                {"point": list(point), "index": list(idx)},
            ))
            return out
        for d, (i, blk, dim) in enumerate(zip(idx, block, shape)):
            start = i * blk
            if i < 0 or start + blk > dim:
                out.append(Finding(
                    R_PL_OOB, FAIL,
                    f"{geom.name}/{what}: grid point {point} maps dim {d} "
                    f"to elements [{start}, {start + blk}) outside "
                    f"[0, {dim}) — Pallas would clamp or wrap this block "
                    f"silently",
                    {"point": list(point), "dim": d, "start": start,
                     "block": blk, "extent": dim},
                ))
                return out  # one witness per operand is enough
    return out


@rule(R_PL_OOB, PALLAS_FAMILY)
def check_pallas_oob(geom: Any) -> list[Finding]:
    """Every block access of every grid step is in bounds."""
    out: list[Finding] = []
    n_ops = {len(geom.in_shapes), len(geom.in_blocks), len(geom.in_index_maps)}
    if len(n_ops) != 1:
        out.append(Finding(
            R_PL_OOB, FAIL,
            f"{geom.name}: operand arity mismatch — {len(geom.in_shapes)} "
            f"shapes, {len(geom.in_blocks)} blocks, "
            f"{len(geom.in_index_maps)} index maps",
            {},
        ))
        return out
    for i, (shape, block, imap) in enumerate(
        zip(geom.in_shapes, geom.in_blocks, geom.in_index_maps)
    ):
        out.extend(_check_operand(geom, f"in[{i}]", shape, block, imap))
    out.extend(_check_operand(
        geom, "out", geom.out_shape, geom.out_block, geom.out_index_map
    ))
    return out


@rule(R_PL_ALIAS, PALLAS_FAMILY)
def check_pallas_out_alias(geom: Any) -> list[Finding]:
    """The output index map is injective across the grid."""
    out: list[Finding] = []
    seen: dict[tuple[int, ...], tuple[int, ...]] = {}
    for point in _grid_points(geom.grid):
        try:
            idx = tuple(int(v) for v in geom.out_index_map(*point))
        except Exception:
            return out  # crash is the oob rule's finding, not an alias
        if idx in seen:
            out.append(Finding(
                R_PL_ALIAS, FAIL,
                f"{geom.name}: grid points {seen[idx]} and {point} both "
                f"write output block {idx} — a write-write race whose "
                f"winner depends on grid iteration order",
                {"block": list(idx), "first": list(seen[idx]),
                 "second": list(point)},
            ))
            return out
        seen[idx] = point
    return out


GEOMETRY_RULES = (check_pallas_oob, check_pallas_out_alias)


def analyze_geometry(geom: Any) -> list[Finding]:
    findings: list[Finding] = []
    for fn in GEOMETRY_RULES:
        findings.extend(fn(geom))
    return findings


# --------------------------------------------------------------------------
# GF dtype-safety AST pass
# --------------------------------------------------------------------------

_WRAP_OPS = (ast.Add, ast.Sub, ast.Mult)
_PROP_OPS = (ast.LShift, ast.RShift, ast.BitAnd, ast.BitOr, ast.BitXor)
_REDUCTIONS = ("sum", "prod")
_MATMULS = ("dot_general", "dot", "matmul")


def _is_uint8_marker(node: ast.expr) -> bool:
    """Does this expression *name* the uint8 dtype (jnp/np.uint8)?"""
    if isinstance(node, ast.Attribute):
        return node.attr == "uint8"
    if isinstance(node, ast.Name):
        return node.id == "uint8"
    return False


def _dtype_kw(call: ast.Call) -> ast.expr | None:
    for kw in call.keywords:
        if kw.arg == "dtype":
            return kw.value
    return None


class _U8State:
    """Per-function uint8-ness environment (names known to hold uint8)."""

    def __init__(self) -> None:
        self.names: set[str] = set()

    def is_u8(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.names
        if isinstance(node, ast.Subscript):
            v = node.value
            if isinstance(v, ast.Name) and v.id.endswith("_ref"):
                return True  # a Pallas ref load — payload bytes
            return self.is_u8(v)
        if isinstance(node, ast.BinOp):
            return self.is_u8(node.left) or self.is_u8(node.right)
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Attribute):
                if f.attr == "astype":
                    # explicit cast: uint8 iff the target dtype is uint8
                    return bool(node.args) and _is_uint8_marker(node.args[0])
                if _is_uint8_marker(f):  # jnp.uint8(...)
                    return True
                # shape-preserving methods propagate the receiver
                if f.attr in ("reshape", "transpose", "ravel", "squeeze"):
                    return self.is_u8(f.value)
            if isinstance(f, ast.Name) and f.id == "uint8":
                return True
            dtype = _dtype_kw(node)
            if dtype is not None:
                return _is_uint8_marker(dtype)
        return False


def _scan_expr(
    path: str, fn_name: str, node: ast.expr, env: _U8State,
    findings: list[Finding],
) -> None:
    for sub in ast.walk(node):
        if isinstance(sub, ast.BinOp):
            if isinstance(sub.op, _WRAP_OPS) and (
                env.is_u8(sub.left) or env.is_u8(sub.right)
            ):
                findings.append(Finding(
                    R_PL_DTYPE, FAIL,
                    f"{path}:{sub.lineno} ({fn_name}): "
                    f"{type(sub.op).__name__} on a uint8 operand wraps "
                    f"mod 256 silently — GF(2^8) addition is XOR, and "
                    f"widening must be explicit",
                    {"path": path, "line": sub.lineno, "fn": fn_name,
                     "op": type(sub.op).__name__},
                ))
            if isinstance(sub.op, ast.MatMult) and (
                env.is_u8(sub.left) or env.is_u8(sub.right)
            ):
                findings.append(Finding(
                    R_PL_DTYPE, FAIL,
                    f"{path}:{sub.lineno} ({fn_name}): '@' on a uint8 "
                    f"operand accumulates in uint8 — use dot_general with "
                    f"preferred_element_type",
                    {"path": path, "line": sub.lineno, "fn": fn_name},
                ))
        elif isinstance(sub, ast.Call):
            f = sub.func
            name = f.attr if isinstance(f, ast.Attribute) else (
                f.id if isinstance(f, ast.Name) else ""
            )
            if name in _REDUCTIONS:
                operand: ast.expr | None = None
                if isinstance(f, ast.Attribute) and not sub.args:
                    operand = f.value  # x.sum() method form
                elif sub.args:
                    operand = sub.args[0]
                if (
                    operand is not None
                    and env.is_u8(operand)
                    and _dtype_kw(sub) is None
                ):
                    findings.append(Finding(
                        R_PL_DTYPE, FAIL,
                        f"{path}:{sub.lineno} ({fn_name}): {name}() over a "
                        f"uint8 operand without an explicit dtype wraps "
                        f"mod 256 once the reduction exceeds 255",
                        {"path": path, "line": sub.lineno, "fn": fn_name,
                         "reduction": name},
                    ))
            if name in _MATMULS and not any(
                kw.arg == "preferred_element_type" for kw in sub.keywords
            ):
                findings.append(Finding(
                    R_PL_DTYPE, FAIL,
                    f"{path}:{sub.lineno} ({fn_name}): {name}() without "
                    f"preferred_element_type accumulates in the input "
                    f"dtype — int8 bitplane products overflow at K >= 16",
                    {"path": path, "line": sub.lineno, "fn": fn_name,
                     "call": name},
                ))


def _scan_stmts(
    path: str, fn_name: str, stmts: Iterable[ast.stmt], env: _U8State,
    findings: list[Finding],
) -> None:
    for stmt in stmts:
        # check expressions with the env as of *before* this statement
        for expr in ast.iter_child_nodes(stmt):
            if isinstance(expr, ast.expr):
                _scan_expr(path, fn_name, expr, env, findings)
        if isinstance(stmt, ast.Assign):
            u8 = env.is_u8(stmt.value)
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name):
                    (env.names.add if u8 else env.names.discard)(tgt.id)
        elif isinstance(stmt, ast.AugAssign):
            if isinstance(stmt.target, ast.Name) and env.is_u8(stmt.value):
                env.names.add(stmt.target.id)
        # conservative: nested blocks share the same env
        for block in ("body", "orelse", "finalbody"):
            inner = getattr(stmt, block, None)
            if inner:
                _scan_stmts(path, fn_name, inner, env, findings)


@rule(R_PL_DTYPE, PALLAS_FAMILY)
def check_gf_dtype(path: str, source: str) -> list[Finding]:
    """AST dtype-safety pass over one kernel source file."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(
            R_PL_DTYPE, FAIL,
            f"{path}: does not parse: {e}", {"path": path},
        )]
    findings: list[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            _scan_stmts(path, node.name, node.body, _U8State(), findings)
    return findings


# --------------------------------------------------------------------------
# Sweep entry points
# --------------------------------------------------------------------------

# (r, k, b, block_b) shapes swept by default — bracketing the coding
# shapes the paper's configurations actually hit (ops.choose_block_b
# picks block_b <= 4096, lane-aligned).
GEOMETRY_SHAPES: tuple[tuple[int, int, int, int], ...] = (
    (2, 4, 1024, 256),
    (3, 6, 4096, 512),
    (4, 8, 2048, 512),
    (3, 9, 65536, 4096),
)

_KERNEL_MODULES = ("repro.kernels.gf_matmul", "repro.kernels.ops")


def kernel_source_paths() -> tuple[str, ...]:
    """Absolute paths of the swept kernel sources (CWD-independent)."""
    import importlib.util

    paths = []
    for mod in _KERNEL_MODULES:
        spec = importlib.util.find_spec(mod)
        if spec is None or spec.origin is None:
            raise RuntimeError(f"cannot locate kernel module {mod}")
        paths.append(spec.origin)
    return tuple(paths)


def verify_kernel_geometry(
    geom: Any, *, family: str = PALLAS_FAMILY
) -> LoweredRecord:
    return LoweredRecord(
        label=geom.name, family=family,
        artifact=f"{geom.name}{tuple(geom.grid)} "
                 f"out={tuple(geom.out_shape)}",
        findings=analyze_geometry(geom),
        info={
            "grid": list(geom.grid),
            "grid_points": int(math.prod(geom.grid)),
            "operands": len(geom.in_shapes) + 1,
        },
    )


def verify_kernel_source(
    path: str, source: str | None = None, *, family: str = PALLAS_FAMILY
) -> LoweredRecord:
    if source is None:
        with open(path) as f:
            source = f.read()
    import os

    short = "/".join(path.replace(os.sep, "/").split("/")[-3:])
    return LoweredRecord(
        label=short, family=family, artifact=f"source:{short}",
        findings=check_gf_dtype(path, source),
        info={"bytes": len(source)},
    )


# --------------------------------------------------------------------------
# Mutations
# --------------------------------------------------------------------------

PALLAS_MUTATIONS: dict[str, str] = {
    "pallas_oob_index_map": R_PL_OOB,
    "pallas_alias_out": R_PL_ALIAS,
    "pallas_sum_no_dtype": R_PL_DTYPE,
    "pallas_acc_wrap": R_PL_DTYPE,
}


def mutate_pallas(
    geom: Any, source: str, mutation: str
) -> tuple[str, Any]:
    """Corrupt either the geometry or the kernel source.

    Returns ("geometry", mutated_geom) or ("source", mutated_source).
    """
    if mutation == "pallas_oob_index_map":
        # payload tile marches one block past the end of the array
        maps = list(geom.in_index_maps)
        maps[1] = lambda j: (0, j + 1)
        return "geometry", dataclasses.replace(
            geom, in_index_maps=tuple(maps)
        )
    if mutation == "pallas_alias_out":
        # every grid step writes output block (0, 0)
        return "geometry", dataclasses.replace(
            geom, out_index_map=lambda j: (0, 0)
        )
    if mutation == "pallas_sum_no_dtype":
        # drop the explicit accumulator dtype of the pack-bits reduction
        needle = "axis=1, dtype=jnp.uint8"
        if needle not in source:
            raise ValueError(f"mutation target {needle!r} not in source")
        return "source", source.replace(needle, "axis=1", 1)
    if mutation == "pallas_acc_wrap":
        needle = "preferred_element_type=jnp.int32,"
        if needle not in source:
            raise ValueError(f"mutation target {needle!r} not in source")
        return "source", source.replace(needle, "", 1)
    raise ValueError(f"unknown pallas mutation {mutation!r}")


def pallas_mutation_findings(
    geom: Any, source: str, path: str, mutation: str
) -> list[Finding]:
    """Findings of the whole pallas family over one mutated artifact
    (the untouched artifact of the other kind is analyzed pristine)."""
    kind, mutated = mutate_pallas(geom, source, mutation)
    if kind == "geometry":
        return analyze_geometry(mutated) + check_gf_dtype(path, source)
    return analyze_geometry(geom) + check_gf_dtype(path, mutated)


__all__ = [
    "R_PL_OOB", "R_PL_ALIAS", "R_PL_DTYPE", "PALLAS_MUTATIONS",
    "GEOMETRY_SHAPES", "kernel_source_paths", "analyze_geometry",
    "check_gf_dtype", "verify_kernel_geometry", "verify_kernel_source",
    "mutate_pallas", "pallas_mutation_findings",
]
