"""Static analysis over *lowered* artifacts (``repro.check.lowered``).

``repro.check.plan`` proves repair plans optimal at the DAG level; this
package proves the lowering layers preserved that optimality:

* :mod:`.spmd` — the static SPMD collective-permute schedule
  (``SpmdRepairSpec``): partial-permutation validity, row liveness,
  dead-device silence, decode-gather consistency, exact per-pod byte
  accounting against Eq. (3), rotation balance.
* :mod:`.shard_rules` — sharding-rule tables resolved against every
  model config: axis hygiene, divisibility/fallback guarantees, pod-
  axis containment.
* :mod:`.pallas` — Pallas kernel geometry swept symbolically over the
  full grid (in-bounds, write-disjoint) plus a GF(2^8) dtype-safety
  AST pass over the kernel sources.

Every rule has a paired mutation in ``LOWERED_MUTATIONS``;
:func:`self_test_lowered` corrupts a known-good artifact per mutation
and demands the corruption is caught by *exactly* its owning rule —
stronger than the plan-layer self-test, which only demands the owner
fires.  ``python -m tools.run_check --self-test`` runs both.
"""
from __future__ import annotations

from typing import Any, Callable

from ..report import FAIL, CheckReport, Finding, LoweredRecord
from . import pallas, shard_rules, spmd
from .base import (
    LOWERED_FAMILIES,
    LOWERED_RULES,
    PALLAS_FAMILY,
    SHARD_FAMILY,
    SPMD_FAMILY,
    fail_rules,
    rules_for,
)

# ------------------------------------------------------------------- sweep
# family -> artifact parameters; mirrors plan.REGISTRY_SWEEP in spirit.
LOWERED_SWEEP: dict[str, Any] = {
    SPMD_FAMILY: [
        ("DRC", 6, 4, 3),
        ("DRC", 9, 6, 3),
        ("DRC", 9, 5, 3),
        ("DRC", 8, 6, 4),
        ("RS", 9, 6, 3),
    ],
    SHARD_FAMILY: "ARCHS x MODES",  # resolved at sweep time
    PALLAS_FAMILY: list(pallas.GEOMETRY_SHAPES),
}


def run_lowered_sweep() -> list[LoweredRecord]:
    """Analyze every registered lowered artifact; one record each."""
    from repro.configs import ARCHS, get_config
    from repro.core.codes.registry import make_code
    from repro.dist.sharding import MODES
    from repro.kernels.gf_matmul import gf_matmul_geometry

    records: list[LoweredRecord] = []
    for fam, n, k, r in LOWERED_SWEEP[SPMD_FAMILY]:
        code = make_code(fam, n, k, r=r)
        records.extend(spmd.verify_spmd_lowering(code))
    for arch in ARCHS:
        config = get_config(arch)
        for mode in MODES:
            records.append(shard_rules.verify_shard_rules(config, mode))
    for shape in LOWERED_SWEEP[PALLAS_FAMILY]:
        records.append(
            pallas.verify_kernel_geometry(gf_matmul_geometry(*shape))
        )
    for path in pallas.kernel_source_paths():
        records.append(pallas.verify_kernel_source(path))
    return records


def lowered_report() -> CheckReport:
    """A CheckReport holding only the lowered sweep."""
    return CheckReport(lowered_records=run_lowered_sweep())


# --------------------------------------------------------------- self-test
# mutation name -> (family, owning rule id)
LOWERED_MUTATIONS: dict[str, tuple[str, str]] = {
    **{m: (SPMD_FAMILY, r) for m, r in spmd.SPMD_MUTATIONS.items()},
    **{m: (SHARD_FAMILY, r) for m, r in shard_rules.SHARD_MUTATIONS.items()},
    **{m: (PALLAS_FAMILY, r) for m, r in pallas.PALLAS_MUTATIONS.items()},
}


def _spmd_mutation_fails(mutation: str) -> set[str]:
    from repro.core.codes.registry import make_code
    from repro.dist.collectives import plan_to_spmd

    code = make_code("DRC", 6, 4, r=3)
    plan = code.repair_plan(0)
    spec = plan_to_spmd(code, plan)
    mutated = spmd.mutate_spmd(code, plan, spec, mutation)
    return fail_rules(spmd.spmd_mutation_findings(code, plan, mutated))


def _shard_mutation_fails(mutation: str) -> set[str]:
    from repro.configs import get_config
    from repro.dist.sharding import make_rules, resolve_spec

    art = shard_rules.ShardArtifact(
        rules=make_rules("tp", multi_pod=True),
        config=get_config("command_r_35b"),
        meshes=(
            *shard_rules.MULTI_POD_MESHES,
            *shard_rules.CANONICAL_MESHES,
        ),
        resolver=resolve_spec,
    )
    mutated = shard_rules.mutate_shard(art, mutation)
    return fail_rules(shard_rules.analyze_shard_artifact(mutated))


def _pallas_mutation_fails(mutation: str) -> set[str]:
    from repro.kernels.gf_matmul import gf_matmul_geometry

    geom = gf_matmul_geometry(3, 6, 4096, 512)
    path = pallas.kernel_source_paths()[0]
    with open(path) as f:
        source = f.read()
    return fail_rules(
        pallas.pallas_mutation_findings(geom, source, path, mutation)
    )


_MUTATION_RUNNERS: dict[str, Callable[[str], set[str]]] = {
    SPMD_FAMILY: _spmd_mutation_fails,
    SHARD_FAMILY: _shard_mutation_fails,
    PALLAS_FAMILY: _pallas_mutation_fails,
}


def self_test_lowered() -> list[tuple[str, str, bool, bool]]:
    """Corrupt one known-good artifact per mutation.

    Returns (mutation, owning rule, caught, exclusive) rows; the gate
    demands caught AND exclusive — the corruption must FAIL exactly the
    rule that owns it, proving both coverage and rule independence.
    """
    rows: list[tuple[str, str, bool, bool]] = []
    for mutation, (family, owner) in LOWERED_MUTATIONS.items():
        fails = _MUTATION_RUNNERS[family](mutation)
        rows.append((mutation, owner, owner in fails, fails == {owner}))
    return rows


__all__ = [
    "LOWERED_FAMILIES", "LOWERED_MUTATIONS", "LOWERED_RULES",
    "LOWERED_SWEEP", "PALLAS_FAMILY", "SHARD_FAMILY", "SPMD_FAMILY",
    "FAIL", "Finding", "LoweredRecord", "fail_rules", "lowered_report",
    "pallas", "rules_for", "run_lowered_sweep", "self_test_lowered",
    "shard_rules", "spmd",
]
