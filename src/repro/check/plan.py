"""Static verification of repair plans against the DoubleR theory.

Every `RepairPlan` is data — explicit GF(256) matrices on an explicit
DAG — so the paper's structural claims (arXiv 1704.03696 §3–§4) can be
checked *without executing a single payload byte*:

* **DAG well-formedness** — every `Send` originates at a surviving node,
  relayer input widths match what rack-mates actually ship, the decode
  matrix has one column per unit reaching the target, and the recorded
  ``target_order`` provenance matches the canonical unit order.
* **Symbolic decodability** — propagating coefficient vectors through
  the DAG, ``decode @ unit_coeffs`` must reproduce the failed node's
  generator rows; additionally the decode matrix must have full rank α
  and no relayer matrix may drop rank the decode needs downstream.
* **Traffic optimality** — the plan's cross-rack blocks must equal the
  family's closed form (Eq. (1)/(2)/(3)); for DRC that closed form *is*
  the lower bound, so any regression in a construction trips this rule.
  Per-relayer cross traffic must be balanced within one unit (Goal 8).
* **Placement invariants** — helpers ship to relayers only within their
  own rack, relayers live outside the target rack, and the plan carries
  the code's own placement (so rack failure tolerance is unchanged).

Each rule is a registered function emitting `Finding`s with a witness;
``verify_plan`` runs the catalog over one plan, ``verify_code`` sweeps
every failed node, and ``run_registry_sweep`` covers every registered
family across ≥ 3 (n, k, r) shapes.  ``self_test`` deliberately corrupts
a known-good plan three ways and asserts each corruption is caught by
the rule that owns it — the CI mutation test.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable

import numpy as np

from repro.core import gf
from repro.core.code_base import ErasureCode, drc_min_cross_rack_blocks
from repro.core.codes import make_code
from repro.core.codes.stripwise import StripwiseRS
from repro.core.repair import TARGET, RepairPlan, Send, build_target_order

from .errors import PlanError
from .report import FAIL, PASS, WARN, CheckReport, Finding, PlanRecord

# --------------------------------------------------------------------------
# Rule registry
# --------------------------------------------------------------------------

RuleFn = Callable[[ErasureCode, RepairPlan], list[Finding]]

PLAN_RULES: dict[str, RuleFn] = {}

# Rule ids referenced from more than one place.
R_SEND_MATRIX = "plan.dag.send-matrix"
R_SRC_SURVIVING = "plan.dag.src-surviving"
R_DUPLICATE_SEND = "plan.dag.duplicate-send"
R_RELAYER_INPUT = "plan.dag.relayer-input"
R_TARGET_ORDER = "plan.dag.target-order"
R_DECODE_SHAPE = "plan.dag.decode-shape"
R_COEFFICIENTS = "plan.decode.coefficients"
R_DECODE_RANK = "plan.decode.rank"
R_UNIT_RANK = "plan.decode.unit-rank"
R_SEND_RANK = "plan.decode.send-rank"
R_CROSS_BOUND = "plan.traffic.cross-lower-bound"
R_RELAYER_BALANCE = "plan.traffic.relayer-balance"
R_HELPER_RACKS = "plan.placement.helper-racks"
R_TOLERANCE = "plan.placement.tolerance"
R_STRIP_SYSTEMATIC = "code.stripwise.systematic"
R_STRIP_SET_MDS = "code.stripwise.set-mds"
R_STRIP_DISTINCT = "code.stripwise.sets-distinct"
R_SPMD_CROSS = "spmd.cross_bytes"


def rule(rule_id: str) -> Callable[[RuleFn], RuleFn]:
    """Register a plan-verification rule under a stable id."""

    def deco(fn: RuleFn) -> RuleFn:
        if rule_id in PLAN_RULES:
            raise ValueError(f"duplicate rule id {rule_id!r}")
        PLAN_RULES[rule_id] = fn
        return fn

    return deco


def _all_sends(plan: RepairPlan) -> list[tuple[str, Send]]:
    return [("node", s) for s in plan.node_sends] + [
        ("relayer", s) for s in plan.relayer_sends
    ]


# --------------------------------------------------------------------------
# Part 1 — DAG well-formedness
# --------------------------------------------------------------------------


@rule(R_SEND_MATRIX)
def _check_send_matrices(code: ErasureCode, plan: RepairPlan) -> list[Finding]:
    """Every Send matrix is 2-D uint8 with at least one input column."""
    out: list[Finding] = []
    for kind, s in _all_sends(plan):
        m = s.matrix
        if not isinstance(m, np.ndarray) or m.ndim != 2 or m.dtype != np.uint8:
            out.append(Finding(
                R_SEND_MATRIX, FAIL,
                f"{kind} send {s.src}->{s.dst}: matrix must be 2-D uint8",
                {"src": s.src, "dst": s.dst,
                 "shape": getattr(m, "shape", None),
                 "dtype": str(getattr(m, "dtype", type(m).__name__))},
            ))
        elif m.shape[0] == 0 or m.shape[1] == 0:
            out.append(Finding(
                R_SEND_MATRIX, FAIL,
                f"{kind} send {s.src}->{s.dst}: empty matrix {m.shape}",
                {"src": s.src, "dst": s.dst, "shape": m.shape},
            ))
    return out


@rule(R_SRC_SURVIVING)
def _check_src_surviving(code: ErasureCode, plan: RepairPlan) -> list[Finding]:
    """Every edge originates at a surviving node and ends at a legal dst."""
    out: list[Finding] = []
    n = code.n
    relayers = {s.src for s in plan.relayer_sends}
    for kind, s in _all_sends(plan):
        if not (0 <= s.src < n) or s.src == plan.failed:
            out.append(Finding(
                R_SRC_SURVIVING, FAIL,
                f"{kind} send from non-surviving node {s.src} "
                f"(failed={plan.failed}, n={n})",
                {"src": s.src, "dst": s.dst, "failed": plan.failed},
            ))
        if kind == "relayer":
            if s.dst != TARGET:
                out.append(Finding(
                    R_SRC_SURVIVING, FAIL,
                    f"relayer send {s.src}->{s.dst} must go to the target",
                    {"src": s.src, "dst": s.dst},
                ))
        elif s.dst != TARGET:
            if not (0 <= s.dst < n) or s.dst == plan.failed or s.dst == s.src:
                out.append(Finding(
                    R_SRC_SURVIVING, FAIL,
                    f"node send {s.src}->{s.dst}: dst is not a surviving "
                    f"helper or the target",
                    {"src": s.src, "dst": s.dst, "failed": plan.failed},
                ))
            elif s.dst not in relayers:
                out.append(Finding(
                    R_SRC_SURVIVING, FAIL,
                    f"node send {s.src}->{s.dst}: dst never relays "
                    f"(its units are dropped)",
                    {"src": s.src, "dst": s.dst, "relayers": sorted(relayers)},
                ))
    return out


@rule(R_DUPLICATE_SEND)
def _check_duplicate_sends(code: ErasureCode, plan: RepairPlan) -> list[Finding]:
    """At most one Send per (src, dst) edge — duplicates silently alias in
    the coefficient propagation (dict keyed by edge)."""
    out: list[Finding] = []
    seen: set[tuple[int, int]] = set()
    for s in plan.node_sends:
        edge = (s.src, s.dst)
        if edge in seen:
            out.append(Finding(
                R_DUPLICATE_SEND, FAIL,
                f"duplicate node send on edge {s.src}->{s.dst}",
                {"src": s.src, "dst": s.dst},
            ))
        seen.add(edge)
    rseen: set[int] = set()
    for s in plan.relayer_sends:
        if s.src in rseen:
            out.append(Finding(
                R_DUPLICATE_SEND, FAIL,
                f"duplicate relayer send from node {s.src}",
                {"src": s.src},
            ))
        rseen.add(s.src)
    return out


@rule(R_RELAYER_INPUT)
def _check_relayer_inputs(code: ErasureCode, plan: RepairPlan) -> list[Finding]:
    """Matrix input widths match what each sender actually holds/receives:
    node sends consume the sender's α subblocks; a relayer consumes its
    own α subblocks ++ the units its rack-mates shipped to it."""
    out: list[Finding] = []
    alpha = plan.alpha
    for s in plan.node_sends:
        if s.matrix.ndim == 2 and s.matrix.shape[1] != alpha:
            out.append(Finding(
                R_RELAYER_INPUT, FAIL,
                f"node send {s.src}->{s.dst}: input dim {s.matrix.shape[1]} "
                f"!= alpha={alpha}",
                {"src": s.src, "dst": s.dst, "got": s.matrix.shape[1],
                 "want": alpha},
            ))
    for s in plan.relayer_sends:
        received = sum(x.units for x in plan.node_sends if x.dst == s.src)
        want = alpha + received
        if s.matrix.ndim == 2 and s.matrix.shape[1] != want:
            out.append(Finding(
                R_RELAYER_INPUT, FAIL,
                f"relayer {s.src}: input dim {s.matrix.shape[1]} != "
                f"alpha + received = {alpha} + {received}",
                {"relayer": s.src, "got": s.matrix.shape[1], "want": want},
            ))
    return out


@rule(R_TARGET_ORDER)
def _check_target_order(code: ErasureCode, plan: RepairPlan) -> list[Finding]:
    """Recorded unit provenance must match the canonical target order."""
    want = build_target_order(plan.node_sends, plan.relayer_sends)
    if plan.target_order != want:
        return [Finding(
            R_TARGET_ORDER, FAIL,
            "target_order does not match canonical unit order "
            "(sends to target sorted by src, then relayers by src)",
            {"recorded": list(plan.target_order), "canonical": want},
        )]
    return []


@rule(R_DECODE_SHAPE)
def _check_decode_shape(code: ErasureCode, plan: RepairPlan) -> list[Finding]:
    """decode is (α, total units reaching the target), 2-D uint8."""
    d = plan.decode
    if not isinstance(d, np.ndarray) or d.ndim != 2 or d.dtype != np.uint8:
        return [Finding(
            R_DECODE_SHAPE, FAIL,
            "decode matrix must be a 2-D uint8 ndarray",
            {"shape": getattr(d, "shape", None),
             "dtype": str(getattr(d, "dtype", type(d).__name__))},
        )]
    total_units = sum(
        s.units for s in plan.node_sends if s.dst == TARGET
    ) + sum(s.units for s in plan.relayer_sends)
    want = (plan.alpha, total_units)
    if d.shape != want:
        return [Finding(
            R_DECODE_SHAPE, FAIL,
            f"decode shape {d.shape} != (alpha, total units at target) "
            f"= {want}",
            {"got": d.shape, "want": want},
        )]
    return []


# --------------------------------------------------------------------------
# Part 2 — symbolic decodability
# --------------------------------------------------------------------------


def _unit_coeffs(code: ErasureCode, plan: RepairPlan) -> np.ndarray | Finding:
    """Coefficient rows of every unit reaching the target, or a Finding
    classifying why they cannot be derived (PlanError from the plan)."""
    try:
        return plan._target_unit_coeffs(code.all_node_coeffs())
    except PlanError as e:
        return Finding(e.rule or R_TARGET_ORDER, FAIL, str(e), dict(e.context))
    except (ValueError, IndexError, KeyError) as e:
        return Finding(
            R_COEFFICIENTS, FAIL,
            f"coefficient propagation failed: {type(e).__name__}: {e}", {},
        )


@rule(R_COEFFICIENTS)
def _check_coefficients(code: ErasureCode, plan: RepairPlan) -> list[Finding]:
    """decode @ unit_coeffs must equal the failed node's generator rows."""
    uc = _unit_coeffs(code, plan)
    if isinstance(uc, Finding):
        return [uc]
    if plan.decode.ndim != 2 or plan.decode.shape[1] != uc.shape[0]:
        return []  # shape defect already owned by plan.dag.decode-shape
    got = gf.gf_matmul(plan.decode, uc)
    want = code.node_coeffs(plan.failed)
    if not np.array_equal(got, want):
        bad = sorted(np.nonzero(np.any(got != want, axis=1))[0].tolist())
        return [Finding(
            R_COEFFICIENTS, FAIL,
            f"decode does not reproduce node {plan.failed}'s generator rows "
            f"(subblocks {bad} differ)",
            {"failed": plan.failed, "bad_subblocks": bad},
        )]
    return []


@rule(R_DECODE_RANK)
def _check_decode_rank(code: ErasureCode, plan: RepairPlan) -> list[Finding]:
    """The decode matrix must have full rank α (no dead output row)."""
    if plan.decode.ndim != 2:
        return []
    rank = gf.gf_rank(plan.decode)
    if rank < plan.alpha:
        return [Finding(
            R_DECODE_RANK, FAIL,
            f"decode matrix rank {rank} < alpha = {plan.alpha}",
            {"rank": rank, "alpha": plan.alpha},
        )]
    return []


@rule(R_UNIT_RANK)
def _check_unit_rank(code: ErasureCode, plan: RepairPlan) -> list[Finding]:
    """The units reaching the target must span the failed node's rows —
    i.e. no relayer matrix dropped rank the decode needs downstream."""
    uc = _unit_coeffs(code, plan)
    if isinstance(uc, Finding):
        return []  # already reported by plan.decode.coefficients
    g_f = code.node_coeffs(plan.failed)
    base = gf.gf_rank(uc)
    joint = gf.gf_rank(np.concatenate([uc, g_f], axis=0))
    if joint > base:
        return [Finding(
            R_UNIT_RANK, FAIL,
            f"target units span rank {base} but need {joint} to cover "
            f"node {plan.failed}'s rows — a relayer/node matrix dropped "
            f"needed rank",
            {"unit_rank": base, "needed_rank": joint, "failed": plan.failed},
        )]
    return []


@rule(R_SEND_RANK)
def _check_send_rank(code: ErasureCode, plan: RepairPlan) -> list[Finding]:
    """Row-deficient send matrices ship redundant units (wasted traffic)."""
    out: list[Finding] = []
    for kind, s in _all_sends(plan):
        if s.matrix.ndim != 2 or 0 in s.matrix.shape:
            continue
        rank = gf.gf_rank(s.matrix)
        if rank < s.units:
            out.append(Finding(
                R_SEND_RANK, WARN,
                f"{kind} send {s.src}->{s.dst} ships {s.units} units but "
                f"only rank {rank} — redundant traffic",
                {"src": s.src, "dst": s.dst, "units": s.units, "rank": rank},
            ))
    return out


# --------------------------------------------------------------------------
# Part 3 — traffic optimality
# --------------------------------------------------------------------------


@rule(R_CROSS_BOUND)
def _check_cross_bound(code: ErasureCode, plan: RepairPlan) -> list[Finding]:
    """Cross-rack blocks equal the family closed form; for DRC that is
    the Eq. (3) lower bound, so exceeding it breaks the paper's claim."""
    try:
        expected = code.theoretical_cross_rack_blocks()
    except NotImplementedError:
        return []
    t = plan.traffic_blocks()
    cross = float(t["cross_rack_blocks"])
    is_drc = isinstance(code, StripwiseRS)
    if is_drc:
        bound = drc_min_cross_rack_blocks(code.n, code.k, code.r)
        if abs(expected - bound) > 1e-9:
            return [Finding(
                R_CROSS_BOUND, FAIL,
                f"DRC closed form {expected} != Eq.(3) lower bound {bound}",
                {"closed_form": expected, "lower_bound": bound},
            )]
    if abs(cross - expected) > 1e-9:
        sev = FAIL if is_drc or cross > expected + 1e-9 else WARN
        return [Finding(
            R_CROSS_BOUND, sev,
            f"cross-rack traffic {cross} blocks != closed form "
            f"{expected} blocks for {code!r} (failed={plan.failed})",
            {"measured": cross, "expected": expected, "failed": plan.failed},
        )]
    return []


@rule(R_RELAYER_BALANCE)
def _check_relayer_balance(code: ErasureCode, plan: RepairPlan) -> list[Finding]:
    """Per-relayer cross-rack traffic balanced within one unit (Goal 8)."""
    t = plan.traffic_blocks()
    per = t["per_relayer_cross"]
    if not isinstance(per, dict) or len(per) < 2:
        return []
    units = {v: blocks * plan.alpha for v, blocks in per.items()}
    lo_v = min(units, key=lambda v: units[v])
    hi_v = max(units, key=lambda v: units[v])
    if units[hi_v] - units[lo_v] > 1.0 + 1e-9:
        return [Finding(
            R_RELAYER_BALANCE, FAIL,
            f"relayer cross traffic unbalanced: node {hi_v} ships "
            f"{units[hi_v]:g} units vs node {lo_v} {units[lo_v]:g}",
            {"per_relayer_units": {str(v): u for v, u in units.items()}},
        )]
    return []


@rule(R_SPMD_CROSS)
def _check_spmd_cross_bytes(code: ErasureCode, plan: RepairPlan) -> list[Finding]:
    """The static SPMD collective schedule (repro.dist.collectives) must
    ship exactly the plan's cross-rack units — and, where the family has
    a closed form, exactly that many: a lowering that silently adds or
    drops cross-pod collective-permute traffic breaks the compiled-HLO
    version of the Eq. (3) claim even if the plan itself is optimal."""
    from repro.dist.collectives import expected_cross_units, plan_to_spmd

    try:
        spec = plan_to_spmd(code, plan)
    except Exception as e:  # a malformed plan must fail loudly, not lower
        return [Finding(
            R_SPMD_CROSS, FAIL,
            f"plan_to_spmd raised {type(e).__name__}: {e}",
            {"failed": plan.failed},
        )]
    out: list[Finding] = []
    scheduled = spec.cross_units
    planned = expected_cross_units(plan)
    if scheduled != planned:
        out.append(Finding(
            R_SPMD_CROSS, FAIL,
            f"SPMD schedule ships {scheduled} cross-pod units but the "
            f"plan accounts {planned} (blocks * alpha)",
            {"scheduled": scheduled, "planned": planned,
             "failed": plan.failed},
        ))
    try:
        closed = code.theoretical_cross_rack_blocks()
    except NotImplementedError:
        closed = None
    if closed is not None:
        want = round(closed * plan.alpha)
        if scheduled != want:
            out.append(Finding(
                R_SPMD_CROSS, FAIL,
                f"SPMD schedule ships {scheduled} cross-pod units != "
                f"family closed form {want} ({closed} blocks * alpha)",
                {"scheduled": scheduled, "closed_form_units": want,
                 "failed": plan.failed},
            ))
    return out


# --------------------------------------------------------------------------
# Part 4 — placement invariants
# --------------------------------------------------------------------------


@rule(R_HELPER_RACKS)
def _check_helper_racks(code: ErasureCode, plan: RepairPlan) -> list[Finding]:
    """Helpers aggregate within their own rack: a send to a relayer must
    stay inner-rack, and relayers live outside the target's rack."""
    out: list[Finding] = []
    pl = plan.placement
    try:
        target_rack = pl.rack_of(plan.failed)
    except ValueError:
        return []  # failed id out of range: owned by placement.tolerance
    for s in plan.node_sends:
        if s.dst == TARGET:
            continue
        if not (0 <= s.src < pl.n and 0 <= s.dst < pl.n):
            continue  # owned by plan.dag.src-surviving
        if pl.rack_of(s.src) != pl.rack_of(s.dst):
            out.append(Finding(
                R_HELPER_RACKS, FAIL,
                f"node {s.src} (rack {pl.rack_of(s.src)}) ships to relayer "
                f"{s.dst} (rack {pl.rack_of(s.dst)}) across racks — "
                f"aggregation must be inner-rack",
                {"src": s.src, "dst": s.dst,
                 "src_rack": pl.rack_of(s.src), "dst_rack": pl.rack_of(s.dst)},
            ))
    for s in plan.relayer_sends:
        if 0 <= s.src < pl.n and pl.rack_of(s.src) == target_rack:
            out.append(Finding(
                R_HELPER_RACKS, FAIL,
                f"relayer {s.src} sits in the target rack {target_rack} — "
                f"relayers exist to cross the gateway once",
                {"relayer": s.src, "target_rack": target_rack},
            ))
    return out


@rule(R_TOLERANCE)
def _check_tolerance(code: ErasureCode, plan: RepairPlan) -> list[Finding]:
    """The plan must carry the code's own placement: same (n, r), same α,
    hence the same rack failure tolerance — a repair never degrades it."""
    out: list[Finding] = []
    if plan.placement != code.placement:
        out.append(Finding(
            R_TOLERANCE, FAIL,
            f"plan placement (n={plan.placement.n}, r={plan.placement.r}) "
            f"!= code placement (n={code.placement.n}, r={code.placement.r})",
            {"plan": (plan.placement.n, plan.placement.r),
             "code": (code.placement.n, code.placement.r)},
        ))
    else:
        m = code.n - code.k
        before = code.placement.rack_failure_tolerance(m)
        after = plan.placement.rack_failure_tolerance(m)
        if after != before:
            out.append(Finding(
                R_TOLERANCE, FAIL,
                f"rack failure tolerance changed by plan: {before} -> {after}",
                {"before": before, "after": after},
            ))
    if plan.alpha != code.alpha:
        out.append(Finding(
            R_TOLERANCE, FAIL,
            f"plan alpha {plan.alpha} != code alpha {code.alpha}",
            {"plan_alpha": plan.alpha, "code_alpha": code.alpha},
        ))
    if not (0 <= plan.failed < code.n):
        out.append(Finding(
            R_TOLERANCE, FAIL,
            f"failed node {plan.failed} out of range for n={code.n}",
            {"failed": plan.failed, "n": code.n},
        ))
    return out


# --------------------------------------------------------------------------
# Entry points
# --------------------------------------------------------------------------


def verify_plan(code: ErasureCode, plan: RepairPlan) -> list[Finding]:
    """Run the full rule catalog over one plan.  Pure/static: no payloads."""
    findings: list[Finding] = []
    for fn in PLAN_RULES.values():
        findings.extend(fn(code, plan))
    return findings


def verify_code(
    code: ErasureCode,
    *,
    family: str = "",
    failed_nodes: Iterable[int] | None = None,
) -> list[PlanRecord]:
    """Verify the repair plan of every failed node of one code."""
    records: list[PlanRecord] = []
    nodes = range(code.n) if failed_nodes is None else failed_nodes
    for f in nodes:
        try:
            plan = code.repair_plan(f)
        except Exception as e:  # constructions may reject a node outright
            records.append(PlanRecord(
                label=repr(code), family=family or code.name,
                n=code.n, k=code.k, r=code.r, failed=f,
                findings=[Finding(
                    "plan.construction", FAIL,
                    f"repair_plan({f}) raised {type(e).__name__}: {e}", {},
                )],
            ))
            continue
        findings = verify_plan(code, plan)
        t = plan.traffic_blocks()
        records.append(PlanRecord(
            label=repr(code), family=family or code.name,
            n=code.n, k=code.k, r=code.r, failed=f,
            findings=findings,
            info={
                "cross_rack_blocks": t["cross_rack_blocks"],
                "inner_rack_blocks": t["inner_rack_blocks"],
                "relayers": plan.relayers,
                "rules_checked": len(PLAN_RULES),
            },
        ))
    return records


# ---------------------------------------------------------- stripwise layer


def verify_stripwise(code: StripwiseRS, *, family: str = "stripwise") -> PlanRecord:
    """Structural checks on the shared strip-wise generator layer: each
    per-set generator is systematic and MDS, and the sets are pairwise
    distinct (geometric independence the Family-1 alignment relies on)."""
    import itertools

    findings: list[Finding] = []
    n, k = code.n, code.k
    sets = getattr(code, "set_gens", None)
    if not sets:
        findings.append(Finding(
            R_STRIP_SYSTEMATIC, FAIL,
            "stripwise code has no per-set generators", {},
        ))
        sets = []
    for t, gt in enumerate(sets):
        if not np.array_equal(gt[:k], np.eye(k, dtype=np.uint8)):
            findings.append(Finding(
                R_STRIP_SYSTEMATIC, FAIL,
                f"set {t} generator is not systematic", {"set": t},
            ))
        for combo in itertools.combinations(range(n), k):
            if gf.gf_rank(gt[list(combo)]) != k:
                findings.append(Finding(
                    R_STRIP_SET_MDS, FAIL,
                    f"set {t} generator not MDS: rows {combo} rank-deficient",
                    {"set": t, "rows": list(combo)},
                ))
                break
    for a, b in itertools.combinations(range(len(sets)), 2):
        if np.array_equal(sets[a][k:], sets[b][k:]):
            findings.append(Finding(
                R_STRIP_DISTINCT, FAIL,
                f"sets {a} and {b} share identical parity geometry — "
                f"interference alignment degenerates",
                {"sets": [a, b]},
            ))
    return PlanRecord(
        label=repr(code), family=family, n=n, k=k, r=code.r, failed=None,
        findings=findings, info={"alpha": code.alpha, "sets": len(sets)},
    )


# ---------------------------------------------------------- SPMD lowering


def verify_spmd(code: ErasureCode, *, family: str = "spmd") -> PlanRecord:
    """Lower every failed node's plan through ``plan_to_spmd`` and check
    the static collective schedule (rule ``spmd.cross_bytes``): one
    record per code summarizing scheduled cross-pod units per node."""
    from repro.dist.collectives import plan_to_spmd

    findings: list[Finding] = []
    cross_by_node: dict[str, int] = {}
    for f in range(code.n):
        plan = code.repair_plan(f)
        findings.extend(_check_spmd_cross_bytes(code, plan))
        try:
            cross_by_node[str(f)] = plan_to_spmd(code, plan).cross_units
        except Exception:
            cross_by_node[str(f)] = -1  # the rule above reported it
    return PlanRecord(
        label=repr(code), family=family, n=code.n, k=code.k, r=code.r,
        failed=None, findings=findings,
        info={"alpha": code.alpha, "cross_units_by_node": cross_by_node},
    )


# --------------------------------------------------------------- the sweep

# Every registered family × ≥ 3 (n, k, r) shapes.  "stripwise" rows check
# the shared generator layer both DRC families build on; "spmd" rows
# check the repro.dist.collectives lowering of DRC-f1 / DRC-f2 / RS.
# Every family carries a fourth shape exercising r > 3 placements
# (more racks than the minimal layering), so rack-count generalization
# is swept, not just the paper's 3-rack walkthroughs.  DRC-f2 is the
# structural exception — its construction (k = 2n/3 - 1) fixes r = 3,
# so its fourth shape scales n instead.
REGISTRY_SWEEP: dict[str, list[tuple[str, int, int, int]]] = {
    "DRC-f1": [
        ("DRC", 6, 4, 3), ("DRC", 8, 6, 4), ("DRC", 9, 6, 3),
        ("DRC", 12, 9, 4),
    ],
    "DRC-f2": [
        ("DRC", 6, 3, 3), ("DRC", 9, 5, 3), ("DRC", 12, 7, 3),
        ("DRC", 15, 9, 3),
    ],
    "RS": [
        ("RS", 6, 4, 6), ("RS", 8, 6, 4), ("RS", 9, 6, 3),
        ("RS", 8, 4, 4),
    ],
    "MSR-Clay": [
        ("MSR", 6, 4, 6), ("MSR", 6, 3, 3), ("MSR", 8, 6, 4),
        ("MSR", 8, 4, 4),
    ],
    "stripwise": [
        ("DRC", 6, 4, 3), ("DRC", 9, 6, 3), ("DRC", 9, 5, 3),
        ("DRC", 12, 9, 4),
    ],
    "spmd": [
        ("DRC", 9, 6, 3), ("DRC", 9, 5, 3), ("RS", 9, 6, 3),
        ("DRC", 8, 6, 4),
    ],
}


def run_registry_sweep(
    sweep: dict[str, list[tuple[str, int, int, int]]] | None = None,
) -> list[PlanRecord]:
    """Statically verify every registered code family across the sweep."""
    sweep = REGISTRY_SWEEP if sweep is None else sweep
    cache: dict[tuple[str, int, int, int], ErasureCode] = {}
    records: list[PlanRecord] = []
    for family, shapes in sweep.items():
        for cfg in shapes:
            fam, n, k, r = cfg
            code = cache.get(cfg)
            if code is None:
                code = cache[cfg] = make_code(fam, n, k, r)
            if family == "stripwise":
                assert isinstance(code, StripwiseRS)
                records.append(verify_stripwise(code, family=family))
            elif family == "spmd":
                records.append(verify_spmd(code, family=family))
            else:
                records.extend(verify_code(code, family=family))
    return records


def sweep_report(
    sweep: dict[str, list[tuple[str, int, int, int]]] | None = None,
) -> CheckReport:
    return CheckReport(plan_records=run_registry_sweep(sweep))


# --------------------------------------------------------- mutation testing

MUTATIONS: dict[str, str] = {
    # mutation name -> rule id that must catch it
    "swap_sends": R_COEFFICIENTS,
    "zero_decode_row": R_DECODE_RANK,
    "off_by_one_target_order": R_TARGET_ORDER,
    "drop_relayer_rank": R_UNIT_RANK,
    "cross_rack_helper": R_HELPER_RACKS,
    "wrong_placement": R_TOLERANCE,
    "inflate_cross_unit": R_SPMD_CROSS,
}


def mutate_plan(plan: RepairPlan, mutation: str) -> RepairPlan:
    """Return a *copy* of `plan` with one deliberate defect injected."""
    if mutation == "swap_sends":
        # swap the matrices of two node sends with equal shapes but
        # different sources — decodability breaks, the DAG stays legal.
        sends = list(plan.node_sends)
        for i in range(len(sends)):
            for j in range(i + 1, len(sends)):
                a, b = sends[i], sends[j]
                if (a.matrix.shape == b.matrix.shape
                        and not np.array_equal(a.matrix, b.matrix)):
                    sends[i] = Send(a.src, a.dst, b.matrix.copy())
                    sends[j] = Send(b.src, b.dst, a.matrix.copy())
                    return dataclasses.replace(plan, node_sends=sends)
        raise ValueError("no swappable send pair in plan")
    if mutation == "zero_decode_row":
        d = plan.decode.copy()
        d[0, :] = 0
        return dataclasses.replace(plan, decode=d)
    if mutation == "off_by_one_target_order":
        order = list(plan.target_order)
        order[0] += 1
        return dataclasses.replace(plan, target_order=order)
    if mutation == "drop_relayer_rank":
        # zero one relayer matrix: its units carry no information, so the
        # surviving units cannot span the failed node's rows any more.
        sends = list(plan.relayer_sends)
        if not sends:
            raise ValueError("plan has no relayer sends")
        s = sends[0]
        sends[0] = Send(s.src, s.dst, np.zeros_like(s.matrix))
        return dataclasses.replace(plan, relayer_sends=sends)
    if mutation == "cross_rack_helper":
        # reroute one helper's units to a relayer in another rack
        sends = list(plan.node_sends)
        relayers = [s.src for s in plan.relayer_sends]
        pl = plan.placement
        for i, s in enumerate(sends):
            if s.dst == TARGET:
                continue
            for v in relayers:
                if pl.rack_of(v) != pl.rack_of(s.src):
                    sends[i] = Send(s.src, v, s.matrix.copy())
                    return dataclasses.replace(plan, node_sends=sends)
        raise ValueError("no reroutable helper send in plan")
    if mutation == "wrong_placement":
        from repro.core.placement import Placement

        flat = Placement(plan.placement.n, plan.placement.n)
        return dataclasses.replace(plan, placement=flat)
    if mutation == "inflate_cross_unit":
        # one relayer ships a redundant extra unit: the plan *and* the
        # SPMD schedule both inflate consistently, so only the closed-
        # form comparison in spmd.cross_bytes pins the regression.
        sends = list(plan.relayer_sends)
        if not sends:
            raise ValueError("plan has no relayer sends")
        s = sends[0]
        sends[0] = Send(s.src, s.dst, np.vstack([s.matrix, s.matrix[:1]]))
        return dataclasses.replace(plan, relayer_sends=sends)
    raise ValueError(f"unknown mutation {mutation!r}")


def self_test(
    cfg: tuple[str, int, int, int] = ("DRC", 6, 4, 3),
    mutations: Iterable[str] | None = None,
) -> list[tuple[str, str, bool]]:
    """Corrupt a known-good plan and assert each defect is caught by the
    rule that owns it.  Returns [(mutation, owning_rule, caught)].

    This is the CI mutation test: a verifier that passes everything is
    worthless, so the gate requires every row here to be ``caught``.
    """
    fam, n, k, r = cfg
    code = make_code(fam, n, k, r)
    base = code.repair_plan(0)
    if any(f.severity == FAIL for f in verify_plan(code, base)):
        raise AssertionError("baseline plan must verify clean before mutating")
    results: list[tuple[str, str, bool]] = []
    for mutation in (MUTATIONS if mutations is None else mutations):
        owner = MUTATIONS[mutation]
        mutated = mutate_plan(base, mutation)
        findings = verify_plan(code, mutated)
        caught = any(f.rule == owner and f.severity == FAIL for f in findings)
        results.append((mutation, owner, caught))
    return results
