"""Machine-readable report model for ``repro.check``.

One `Finding` per rule violation (or informational note), one record per
verified artifact — a (code, failed-node) repair plan, a code-level
structural check, a lowered artifact (SPMD schedule, sharding-rule
table, Pallas kernel geometry), a traced program (jaxpr + HLO of a real
entry point), or a linted source file — and one `CheckReport`
aggregating a whole run.  The JSON schema (version 3; version 1 lacked
``lowered_records``, version 2 lacked ``traced_records``) is stable and
documented in docs/architecture.md; CI uploads it as an artifact so a
failed gate can be diagnosed without re-running the sweep.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any

PASS = "PASS"
WARN = "WARN"
FAIL = "FAIL"

_SEVERITY_ORDER = {PASS: 0, WARN: 1, FAIL: 2}

SCHEMA_VERSION = 3


@dataclass(frozen=True)
class Finding:
    """One rule violation (or note) with a witness for the exact defect."""

    rule: str
    severity: str
    message: str
    witness: dict[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.severity not in _SEVERITY_ORDER:
            raise ValueError(f"bad severity {self.severity!r}")

    def as_dict(self) -> dict[str, Any]:
        return {
            "rule": self.rule,
            "severity": self.severity,
            "message": self.message,
            "witness": _jsonable(self.witness),
        }


@dataclass
class PlanRecord:
    """Verification outcome for one plan (or one code-level check).

    ``failed`` is the repaired node id, or ``None`` for code-level
    records (e.g. the stripwise generator-structure checks).
    """

    label: str  # e.g. "DRC(6,4,3)"
    family: str  # sweep family key, e.g. "DRC-f1", "stripwise"
    n: int
    k: int
    r: int
    failed: int | None
    findings: list[Finding] = field(default_factory=list)
    info: dict[str, Any] = field(default_factory=dict)

    @property
    def status(self) -> str:
        worst = PASS
        for f in self.findings:
            if _SEVERITY_ORDER[f.severity] > _SEVERITY_ORDER[worst]:
                worst = f.severity
        return worst

    def as_dict(self) -> dict[str, Any]:
        return {
            "label": self.label,
            "family": self.family,
            "n": self.n,
            "k": self.k,
            "r": self.r,
            "failed": self.failed,
            "status": self.status,
            "findings": [f.as_dict() for f in self.findings],
            "info": _jsonable(self.info),
        }


@dataclass
class LoweredRecord:
    """Verification outcome for one *lowered* artifact.

    The plan verifier sees GF matrices on a DAG; this record covers what
    comes out of the lowering layers instead — a static SPMD collective
    schedule (``SpmdRepairSpec``), a sharding-rule table resolved
    against a model config, or a Pallas kernel's BlockSpec geometry /
    source.  ``family`` is the lowered sweep key (``spmd-schedule``,
    ``shard-rules``, ``pallas-kernel``); ``artifact`` names the thing
    analyzed, e.g. ``SpmdRepairSpec(DRC(6,4,3), failed=0)``.
    """

    label: str
    family: str
    artifact: str
    findings: list[Finding] = field(default_factory=list)
    info: dict[str, Any] = field(default_factory=dict)

    @property
    def status(self) -> str:
        worst = PASS
        for f in self.findings:
            if _SEVERITY_ORDER[f.severity] > _SEVERITY_ORDER[worst]:
                worst = f.severity
        return worst

    def as_dict(self) -> dict[str, Any]:
        return {
            "label": self.label,
            "family": self.family,
            "artifact": self.artifact,
            "status": self.status,
            "findings": [f.as_dict() for f in self.findings],
            "info": _jsonable(self.info),
        }


@dataclass
class TracedRecord:
    """Verification outcome for one *traced* program.

    The lowered layer analyzes declared artifacts; a traced record
    covers the program XLA actually runs — the jaxpr (plus StableHLO /
    compiled HLO where lowered) of one real entry point, analyzed by
    the ``repro.check.traced`` dataflow rules.  ``kind`` is the program
    class (``repair``, ``kernel``, ``hot-path``, ``checkpoint``);
    ``label`` names the capture, e.g. ``spmd_repair[DRC(6,4,3)
    failed=0]``.
    """

    label: str
    kind: str
    findings: list[Finding] = field(default_factory=list)
    info: dict[str, Any] = field(default_factory=dict)

    @property
    def status(self) -> str:
        worst = PASS
        for f in self.findings:
            if _SEVERITY_ORDER[f.severity] > _SEVERITY_ORDER[worst]:
                worst = f.severity
        return worst

    def as_dict(self) -> dict[str, Any]:
        return {
            "label": self.label,
            "kind": self.kind,
            "status": self.status,
            "findings": [f.as_dict() for f in self.findings],
            "info": _jsonable(self.info),
        }


@dataclass
class LintRecord:
    """AST-lint outcome for one source file."""

    path: str
    findings: list[Finding] = field(default_factory=list)

    @property
    def status(self) -> str:
        worst = PASS
        for f in self.findings:
            if _SEVERITY_ORDER[f.severity] > _SEVERITY_ORDER[worst]:
                worst = f.severity
        return worst

    def as_dict(self) -> dict[str, Any]:
        return {
            "path": self.path,
            "status": self.status,
            "findings": [f.as_dict() for f in self.findings],
        }


@dataclass
class CheckReport:
    """Aggregate of one ``repro.check`` run (plan + lowered sweeps + lint)."""

    plan_records: list[PlanRecord] = field(default_factory=list)
    lowered_records: list[LoweredRecord] = field(default_factory=list)
    traced_records: list[TracedRecord] = field(default_factory=list)
    lint_records: list[LintRecord] = field(default_factory=list)

    def _all_records(
        self,
    ) -> tuple[PlanRecord | LoweredRecord | TracedRecord | LintRecord, ...]:
        return (
            *self.plan_records,
            *self.lowered_records,
            *self.traced_records,
            *self.lint_records,
        )

    # ------------------------------------------------------------ queries
    def counts(self) -> dict[str, int]:
        out = {PASS: 0, WARN: 0, FAIL: 0}
        for rec in self._all_records():
            out[rec.status] += 1
        return out

    @property
    def ok(self) -> bool:
        """True iff no record FAILed (WARNs do not gate)."""
        return self.counts()[FAIL] == 0

    def failures(self) -> list[Finding]:
        return [
            f
            for rec in self._all_records()
            for f in rec.findings
            if f.severity == FAIL
        ]

    # ------------------------------------------------------------- export
    def as_dict(self) -> dict[str, Any]:
        return {
            "version": SCHEMA_VERSION,
            "generated_by": "repro.check",
            "summary": self.counts(),
            "plan_records": [r.as_dict() for r in self.plan_records],
            "lowered_records": [r.as_dict() for r in self.lowered_records],
            "traced_records": [r.as_dict() for r in self.traced_records],
            "lint_records": [r.as_dict() for r in self.lint_records],
        }

    def write_json(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.as_dict(), f, indent=1, sort_keys=True)
        return path


def _jsonable(obj: Any) -> Any:
    """Best-effort conversion of witnesses to JSON-serializable values."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (str, bool, int, float)) or obj is None:
        return obj
    if hasattr(obj, "tolist"):  # numpy scalars/arrays
        return _jsonable(obj.tolist())
    if hasattr(obj, "item"):
        return _jsonable(obj.item())
    return repr(obj)
