"""``repro.check`` — static verification of repair plans + AST linting.

Three layers, all payload-free:

* **Plan verifier** (`repro.check.plan`) — proves every registered
  code's repair plans well-formed, symbolically decodable, bandwidth-
  optimal and placement-safe, straight from their GF(256) matrices.
* **Lowered-layer analyzer** (`repro.check.lowered`) — proves the
  lowering preserved the plan's guarantees: SPMD collective schedules
  (partial-permutation validity, byte accounting, rotation balance),
  sharding-rule tables resolved against every model config, and Pallas
  kernel BlockSpec geometry swept symbolically over the full grid plus
  a GF(2^8) dtype-safety AST pass.
* **AST linter** (`repro.check.ast_rules`) — a dependency-free pass
  over the source tree catching the JAX/Pallas pitfalls that bite this
  codebase (numpy inside jit, traced `if`s, host syncs, leaked spans,
  mutable defaults, stale suppression pragmas).

All run in CI via ``python -m tools.run_check`` and gate merges; see
docs/architecture.md §"Static verification" for the rule catalog.

``repro.core.repair`` imports `PlanError` from ``repro.check.errors``
at module load, so this ``__init__`` keeps everything except the error
types lazy (PEP 562) to stay cycle-free.
"""
from __future__ import annotations

from typing import Any

from .errors import CheckError, PlanError

__all__ = [
    "CheckError",
    "PlanError",
    # report model
    "FAIL", "PASS", "WARN", "CheckReport", "Finding", "LintRecord",
    "LoweredRecord", "PlanRecord",
    # plan verifier
    "MUTATIONS", "PLAN_RULES", "REGISTRY_SWEEP", "mutate_plan",
    "run_registry_sweep", "self_test", "sweep_report", "verify_code",
    "verify_plan", "verify_stripwise",
    # lowered-layer analyzer
    "LOWERED_MUTATIONS", "LOWERED_RULES", "LOWERED_SWEEP",
    "lowered_report", "run_lowered_sweep", "self_test_lowered",
    # AST linter
    "ALL_LINT_RULES", "lint_file", "lint_paths", "lint_source", "lint_tree",
]

_LAZY = {
    "FAIL": "report", "PASS": "report", "WARN": "report",
    "CheckReport": "report", "Finding": "report", "LintRecord": "report",
    "PlanRecord": "report",
    "MUTATIONS": "plan", "PLAN_RULES": "plan", "REGISTRY_SWEEP": "plan",
    "mutate_plan": "plan", "run_registry_sweep": "plan", "self_test": "plan",
    "sweep_report": "plan", "verify_code": "plan", "verify_plan": "plan",
    "verify_stripwise": "plan",
    "LoweredRecord": "report",
    "LOWERED_MUTATIONS": "lowered", "LOWERED_RULES": "lowered",
    "LOWERED_SWEEP": "lowered", "lowered_report": "lowered",
    "run_lowered_sweep": "lowered", "self_test_lowered": "lowered",
    "ALL_LINT_RULES": "ast_rules", "lint_file": "ast_rules",
    "lint_paths": "ast_rules", "lint_source": "ast_rules",
    "lint_tree": "ast_rules",
}


def __getattr__(name: str) -> Any:
    module = _LAZY.get(name)
    if module is None:
        raise AttributeError(f"module 'repro.check' has no attribute {name!r}")
    import importlib

    mod = importlib.import_module(f".{module}", __name__)
    value = getattr(mod, name)
    globals()[name] = value  # cache for subsequent lookups
    return value


def __dir__() -> list[str]:
    return sorted(__all__)
