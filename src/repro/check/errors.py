"""Typed errors for the static-verification layer (``repro.check``).

This module is a dependency-free leaf: ``repro.core.repair`` raises
`PlanError` from deep inside plan construction/verification, and the
verifier rules in ``repro.check.plan`` catch it to classify the failure
under the rule that owns it — so it must be importable from both sides
without creating an import cycle.
"""
from __future__ import annotations

from typing import Any


class CheckError(Exception):
    """Base class for every error raised by ``repro.check``."""


class PlanError(CheckError):
    """A structural defect in a `RepairPlan`, with machine-usable context.

    ``rule`` names the verifier rule that owns this class of defect (see
    the rule catalog in docs/architecture.md); ``context`` carries the
    witness (offending node ids, shapes, orders) so reports can point at
    the exact edge of the DAG that is wrong.
    """

    def __init__(self, message: str, *, rule: str = "", **context: Any):
        super().__init__(message)
        self.rule = rule
        self.context: dict[str, Any] = dict(context)

    def __str__(self) -> str:
        base = super().__str__()
        if self.context:
            ctx = ", ".join(f"{k}={v!r}" for k, v in sorted(self.context.items()))
            return f"{base} [{ctx}]"
        return base
