"""repro — DoubleR repair layering (arXiv 1704.03696) as a jax system.

Importing any ``repro.*`` module installs the jax version shims
(``repro.dist.compat``) so code written against the current sharding
API (``jax.shard_map``, ``jax.set_mesh``, …) also runs on jax 0.4.x.
The install is hasattr-guarded and idempotent: on a jax that already
has the APIs it does nothing.
"""
from repro.dist import compat as _compat

_compat.install()
