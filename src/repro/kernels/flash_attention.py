"""Pallas TPU flash attention (forward) — the serving-path hot spot.

Grid: (batch·kv_heads·groups, q_blocks, kv_blocks); the kv axis is the
innermost (sequential on TPU), so the running (max, sum, acc) state lives
in VMEM scratch across kv steps and is finalized on the last block.
Blocks are MXU-aligned (q_block × head_dim and kv_block × head_dim tiles);
causal masking skips fully-masked kv blocks via `pl.when`.

The pure-JAX `_chunked_attention` in models/attention.py remains the
training path (it differentiates through `jax.checkpoint`); this kernel
targets prefill/decode where the forward pass dominates.  Validated in
interpret mode against `ref.py`'s oracle over shape sweeps
(tests/test_kernels.py::test_flash_*).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, causal: bool, scale: float
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    bq = q_ref.shape[0]
    bk = k_ref.shape[0]
    run = True
    if causal:
        # q block rows span [qi*bq, (qi+1)*bq); kv block cols similar —
        # skip blocks strictly above the diagonal
        run = (ki * bk) <= (qi * bq + bq - 1)

    @pl.when(run if causal else True)
    def _step():
        q = q_ref[...].astype(jnp.float32) * scale  # (bq, d)
        k = k_ref[...].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new
        v = v_ref[...].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(ki == nk - 1)
    def _final():
        o_ref[...] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Sk, kvH, D) -> (B, Sq, H, D).

    GQA is handled in the BlockSpec index map (q head hi reads kv head
    hi // (H/kvH)) — repeated K/V never materializes.
    Sq % block_q == 0 and Sk % block_k == 0 (pad upstream).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    groups = h // kvh  # GQA: q head hi reads kv head hi // groups
    scale = 1.0 / math.sqrt(d)
    qg = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kg = k.transpose(0, 2, 1, 3).reshape(b * kvh, sk, d)
    vg = v.transpose(0, 2, 1, 3).reshape(b * kvh, sk, d)
    nq = sq // block_q
    nk = sk // block_k

    def kv_map(g, i, j):
        # grid head-slot g = bi*h + hi  ->  kv slot bi*kvh + hi // groups
        return ((g // h) * kvh + (g % h) // groups, j, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, causal=causal, scale=scale),
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((None, block_k, d), kv_map),
            pl.BlockSpec((None, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
