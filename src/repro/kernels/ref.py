"""Pure-jnp oracle for the GF(2^8) matmul kernel (log/exp table path)."""
from __future__ import annotations

import jax

from repro.core.gf_jax import gf_matmul_jnp


def gf_matmul_ref(m: jax.Array, x: jax.Array) -> jax.Array:
    """Reference GF(256) product: (R, K) x (K, B) -> (R, B), all uint8."""
    return gf_matmul_jnp(m, x)
