"""Pallas TPU kernel: GF(2^8) matrix × payload product (bitplane MXU form).

This is the compute hot-spot of every erasure-coding operation in the
paper — ISA-L's ``ec_encode_data`` (§5.2).  ISA-L implements GF(2^8)
multiply-accumulate with SSE ``PSHUFB`` 4-bit split-table lookups; TPUs
have no byte-shuffle unit, so a mechanical port would serialize on the
VPU.  We adapt the insight instead: multiplication by a GF(2^8) constant
is an 8×8 bit-matrix over GF(2), hence a full GF(256) matrix product

    Y[r, b] = XOR_j  M[r, j] ⊗ X[j, b]        (⊗ = GF(256) multiply)

is exactly a GF(2) matrix product in "bitplane space":

    bits(Y) = (bits(M) @ bits(X)) mod 2,

an [8R, 8K] × [8K, B] *integer* matmul followed by a parity reduction —
precisely what the 197 TFLOP/s MXU is built for.  XOR-accumulation
becomes ordinary integer accumulation + mod-2.

Layout/tiling:

* The coding matrix is tiny (R, K ≤ a few hundred); its bit-expansion
  ``mb`` ([8R, 8K], int8) is precomputed host-side and stays resident in
  VMEM for the whole kernel (BlockSpec maps every grid step to block
  (0, 0)).
* The payload is tiled along the byte axis in ``block_b``-wide stripes
  (multiples of 128 to keep the lane dimension MXU-aligned).  Each grid
  step unpacks its [K, block_b] uint8 tile into the [8K, block_b]
  bitplane tile in VMEM registers, runs the MXU matmul with int32
  accumulation, takes parity, and packs back to [R, block_b] uint8.
* VMEM working set per step: 8K·block_b (bits) + 8R·8K (matrix) +
  8R·block_b (accumulator) bytes(int8/int32) — block_b is chosen by
  ops.choose_block_b() to stay under the ~16 MiB VMEM budget.

Validated in interpret mode against the pure-jnp log/exp oracle
(``repro.kernels.ref``) across shape/dtype sweeps in
tests/test_kernels.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import obs

IndexMap = Callable[..., tuple[int, ...]]


@dataclasses.dataclass(frozen=True)
class KernelGeometry:
    """Static grid/BlockSpec geometry of one pallas_call.

    This is the single source of truth for the kernel's memory schedule:
    :func:`gf_matmul_pallas` builds its ``BlockSpec``s from it, and the
    lowered-layer verifier (``repro.check.lowered.pallas``) sweeps the
    same object symbolically — every grid step's block offsets are
    evaluated against the full array shapes to prove in-bounds access
    and write-disjointness, so a tiling bug fails the static gate
    instead of corrupting payloads on real hardware.

    Index maps follow Pallas semantics: they map a grid point to *block*
    indices; element offsets are ``index * block_shape``.
    """

    name: str
    grid: tuple[int, ...]
    in_shapes: tuple[tuple[int, ...], ...]  # full operand array shapes
    in_blocks: tuple[tuple[int, ...], ...]  # per-operand block shapes
    in_index_maps: tuple[IndexMap, ...]
    out_shape: tuple[int, ...]
    out_block: tuple[int, ...]
    out_index_map: IndexMap

    def in_specs(self) -> list[pl.BlockSpec]:
        return [
            pl.BlockSpec(block, index_map)
            for block, index_map in zip(self.in_blocks, self.in_index_maps)
        ]

    def out_spec(self) -> pl.BlockSpec:
        return pl.BlockSpec(self.out_block, self.out_index_map)


def gf_matmul_geometry(r: int, k: int, b: int, block_b: int) -> KernelGeometry:
    """Geometry of the bitplane kernel for a (R, K) x (K, B) product.

    The bit-expanded matrix block is pinned to (0, 0) on every grid step
    (resident in VMEM); payload and output march along the byte axis in
    ``block_b``-wide stripes.
    """
    if b % block_b:
        raise ValueError(f"payload width {b} not a multiple of tile {block_b}")
    return KernelGeometry(
        name="gf_matmul",
        grid=(b // block_b,),
        in_shapes=((8 * r, 8 * k), (k, b)),
        in_blocks=((8 * r, 8 * k), (k, block_b)),
        in_index_maps=(lambda j: (0, 0), lambda j: (0, j)),
        out_shape=(r, b),
        out_block=(r, block_b),
        out_index_map=lambda j: (0, j),
    )


def _gf_bitplane_kernel(mb_ref, x_ref, o_ref, *, k: int, r: int):
    """One grid step: o[:, tile] = pack( (mb @ unpack(x[:, tile])) & 1 )."""
    x = x_ref[...]  # (k, tb) uint8
    shifts = jnp.arange(8, dtype=jnp.uint8)
    # unpack to bitplanes, row order (byte j, bit i) -> row 8j+i
    xb = (x[:, None, :] >> shifts[None, :, None]) & jnp.uint8(1)  # (k, 8, tb)
    xb = xb.reshape(8 * k, x.shape[-1]).astype(jnp.int8)
    mb = mb_ref[...]  # (8r, 8k) int8
    acc = jax.lax.dot_general(
        mb,
        xb,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )  # (8r, tb) int32
    bits = (acc & 1).astype(jnp.uint8).reshape(r, 8, x.shape[-1])
    o_ref[...] = jnp.sum(bits << shifts[None, :, None], axis=1, dtype=jnp.uint8)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def gf_matmul_pallas(
    mb: jax.Array, x: jax.Array, *, block_b: int = 512, interpret: bool = False
) -> jax.Array:
    """GF(256) product via the bitplane kernel.

    mb: (8R, 8K) int8 bit-expanded coding matrix (host-precomputed).
    x:  (K, B) uint8 payload; B must be a multiple of block_b.
    returns (R, B) uint8.
    """
    r8, k8 = mb.shape
    r, k = r8 // 8, k8 // 8
    kk, b = x.shape
    if kk != k or b % block_b:
        raise ValueError(f"shape mismatch: mb {mb.shape}, x {x.shape}, tile {block_b}")
    geom = gf_matmul_geometry(r, k, b, block_b)
    # Python body of a @jax.jit function: runs once per (shape, block_b)
    # signature.  The counter therefore counts *retraces* — a growing
    # value in a trace means the caller is churning compilation, which on
    # TPU costs far more than the kernel itself.
    obs.counter_add("kernel.pallas_retrace", 1,
                    shape=f"{r}x{k}x{b}", block_b=str(block_b))
    return pl.pallas_call(
        functools.partial(_gf_bitplane_kernel, k=k, r=r),
        grid=geom.grid,
        in_specs=geom.in_specs(),
        out_specs=geom.out_spec(),
        out_shape=jax.ShapeDtypeStruct(geom.out_shape, jnp.uint8),
        interpret=interpret,
    )(mb, x)
