"""Jitted public wrapper around the Pallas GF(2^8) matmul kernel.

``gf_matmul(m, x)`` is the one entry point the rest of the framework uses
(checkpoint encode/repair, the storage simulator's compute model, the
benchmarks).  It

* bit-expands the GF(256) coding matrix host-side (cached by content),
* pads the payload byte axis to the chosen lane-aligned tile,
* dispatches the Pallas kernel (interpret=True automatically off-TPU),
* falls back to the pure-jnp oracle for payloads too small to tile.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import gf as _gf
from .gf_matmul import gf_matmul_pallas
from .ref import gf_matmul_ref

_LANE = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.lru_cache(maxsize=4096)
def _bitmatrix_cached(key: bytes, shape: tuple[int, int]) -> np.ndarray:
    m = np.frombuffer(key, dtype=np.uint8).reshape(shape)
    return _gf.gf_matrix_to_bitmatrix(m).astype(np.int8)


def bit_expand(m: np.ndarray) -> np.ndarray:
    """(R, K) GF(256) matrix -> (8R, 8K) int8 GF(2) bit-matrix (cached)."""
    m = np.ascontiguousarray(np.asarray(m, dtype=np.uint8))
    return _bitmatrix_cached(m.tobytes(), m.shape)


def choose_block_b(k: int, r: int, vmem_budget: int = 8 * 2**20) -> int:
    """Largest lane-aligned payload tile fitting the VMEM budget.

    Working set per step ≈ bitplanes (8K·tb) + packed in (K·tb) + packed
    out (R·tb) + int32 accumulator (4·8R·tb) bytes + resident matrix.
    """
    per_byte = 8 * k + k + r + 32 * r
    fixed = 64 * r * k
    tb = max(_LANE, ((vmem_budget - fixed) // per_byte) // _LANE * _LANE)
    return int(min(tb, 4096))


def gf_matmul(
    m: np.ndarray | jax.Array,
    x: jax.Array,
    *,
    block_b: int | None = None,
    force_kernel: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """GF(256) coding product: (R, K) @ (K, B) -> (R, B) uint8.

    Under an active `repro.obs` tracer every invocation records a
    ``kernel.gf_matmul`` span with wall-clock and achieved GB/s (payload
    in + out bytes; the timing blocks on the result, so traced runs are
    synchronous).  With tracing off the only extra work is one global
    read — the dispatch path is untouched.
    """
    m_np = np.asarray(m, dtype=np.uint8)
    r, k = m_np.shape
    x = jnp.asarray(x, dtype=jnp.uint8)
    if x.ndim != 2 or x.shape[0] != k:
        raise ValueError(f"payload {x.shape} does not match matrix {m_np.shape}")
    b = x.shape[1]
    if interpret is None:
        interpret = not _on_tpu()
    tracer = obs.current()
    if tracer is None:
        return _dispatch(m_np, x, r, k, b, block_b, force_kernel, interpret)
    t0 = time.perf_counter()
    y = _dispatch(m_np, x, r, k, b, block_b, force_kernel, interpret)
    # traced timing must observe the finished result: sync is the point
    jax.block_until_ready(y)  # check: ignore[host-sync]
    dt = max(time.perf_counter() - t0, 1e-9)
    path = "pallas" if (b >= _LANE and _on_tpu()) or force_kernel else "ref"
    moved = (k + r) * b  # payload bytes in + out
    tracer.record_span("kernel.gf_matmul", dt, cat="kernel", track="kernel",
                       at_s=tracer.now_us() / 1e6 - dt,
                       r=r, k=k, b=b, path=path, gbps=moved / dt / 1e9)
    tracer.counter_add("kernel.gf_matmul.bytes", moved, path=path)
    tracer.counter_add("kernel.gf_matmul.calls", 1, path=path)
    tracer.gauge_set("kernel.gf_matmul.gbps", moved / dt / 1e9, path=path)
    return y


def _dispatch(
    m_np: np.ndarray,
    x: jax.Array,
    r: int,
    k: int,
    b: int,
    block_b: int | None,
    force_kernel: bool,
    interpret: bool,
) -> jax.Array:
    # Off-TPU the Pallas kernel runs in (slow, python-level) interpret
    # mode — it exists for correctness validation; the log/exp oracle is
    # the fast CPU path.  On TPU the kernel is the fast path.
    if (b < _LANE or not _on_tpu()) and not force_kernel:
        return gf_matmul_ref(jnp.asarray(m_np), x)
    tb = block_b or choose_block_b(k, r)
    tb = min(tb, max(_LANE, (b // _LANE) * _LANE))
    pad = (-b) % tb
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    mb = jnp.asarray(bit_expand(m_np))
    y = gf_matmul_pallas(mb, x, block_b=tb, interpret=interpret)
    return y[:, :b] if pad else y


def encode_payload(generator: np.ndarray, data: jax.Array) -> jax.Array:
    """Systematic encode: only compute the parity rows on the data path."""
    ka = generator.shape[1]
    parity = gf_matmul(generator[ka:], data)
    return jnp.concatenate([data, parity], axis=0)
