"""LR schedules: cosine and WSD (warmup-stable-decay, minicpm §4)."""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class ScheduleConfig:
    kind: str = "cosine"  # cosine | wsd | constant
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1  # WSD: last 10% of steps decay
    min_lr_frac: float = 0.1


def learning_rate(step, cfg: ScheduleConfig):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(cfg.warmup_steps, 1))
    if cfg.kind == "constant":
        return cfg.peak_lr * warm
    if cfg.kind == "cosine":
        t = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0,
            1.0,
        )
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
        return cfg.peak_lr * warm * frac
    if cfg.kind == "wsd":
        decay_start = cfg.total_steps * (1.0 - cfg.decay_frac)
        t = jnp.clip(
            (step - decay_start) / jnp.maximum(cfg.total_steps - decay_start, 1),
            0.0,
            1.0,
        )
        # exponential-ish decay to min_lr_frac (minicpm uses 10x drop)
        frac = jnp.exp(jnp.log(cfg.min_lr_frac) * t)
        return cfg.peak_lr * warm * frac
    raise ValueError(cfg.kind)
