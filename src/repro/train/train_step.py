"""The jitted training step: loss, grads, AdamW update, metrics.

`make_train_step` closes over (ArchConfig, TrainConfig) and returns a
function (params, opt_state, batch, step) -> (params, opt_state, metrics)
suitable for jax.jit with explicit in/out shardings (resolved from the
logical axis trees by repro.dist.sharding).  Gradient accumulation runs
as a lax.scan over microbatches.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.models import backbone, common
from repro.models.config import ArchConfig
from .optimizer import AdamWConfig, adamw_update, init_opt_state
from .schedule import ScheduleConfig, learning_rate
from .xent import sharded_xent, vocab_parallel_xent


@dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = field(default_factory=AdamWConfig)
    schedule: ScheduleConfig = field(default_factory=ScheduleConfig)
    microbatches: int = 1
    moe_aux_weight: float = 0.01
    attn_chunk: int = 512
    fused_xent: bool = True  # vocab-parallel tile-fused lm-head + loss
    xent_tile: int = 2048
    accum_dtype: str = "float32"  # grad-accumulation buffer (bf16 for 100B+)
    # constrain per-microbatch grads to the parameter sharding so GSPMD
    # reduce-scatters each microbatch's contribution instead of
    # all-reducing full gradients mb times (§Perf-1)
    shard_grads: bool = True


def loss_fn(params, cfg: ArchConfig, tcfg: TrainConfig, batch):
    if tcfg.fused_xent:
        hidden, aux = backbone.forward_hidden(params, cfg, batch, chunk=tcfg.attn_chunk)
        mesh = common.ambient_mesh()
        loss = vocab_parallel_xent(
            hidden,
            backbone.lm_head_weight(params, cfg),
            batch["labels"],
            cfg.vocab,
            mesh=mesh,
            token_axes=("pod", "data"),
            tile=tcfg.xent_tile,
            logit_scale=cfg.logit_scale,
        )
    else:
        logits, aux = backbone.forward(params, cfg, batch, chunk=tcfg.attn_chunk)
        loss = sharded_xent(logits, batch["labels"], cfg.vocab)
    total = loss + tcfg.moe_aux_weight * aux
    return total, {"xent": loss, "moe_aux": aux}


def _split_micro(batch, n):
    return jax.tree.map(lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)


def _pin_to_specs(grads, param_specs):
    """Pin each grad leaf to its parameter's PartitionSpec: under fsdp
    this turns the per-microbatch gradient all-reduce into a
    reduce-scatter (the grad is only ever consumed shard-wise)."""
    if param_specs is None:
        return grads
    return jax.tree.map(
        lambda g, s: jax.lax.with_sharding_constraint(g, s),
        grads,
        param_specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig, param_specs=None):
    def train_step(params, opt_state, batch, step):
        if tcfg.microbatches > 1:
            micro = _split_micro(batch, tcfg.microbatches)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (l, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, cfg, tcfg, mb
                )
                if tcfg.shard_grads:
                    g = _pin_to_specs(g, param_specs)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), metrics

            adt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[tcfg.accum_dtype]
            zero = jax.tree.map(
                lambda p: jnp.zeros(
                    p.shape, adt if p.dtype == jnp.bfloat16 else p.dtype
                ),
                params,
            )
            (grads, loss_sum), metrics = jax.lax.scan(
                accum, (zero, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
            loss = loss_sum / tcfg.microbatches
            metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, cfg, tcfg, batch
            )
        lr = learning_rate(step, tcfg.schedule)
        params, opt_state, gnorm = adamw_update(
            params, grads, opt_state, lr, tcfg.optimizer
        )
        out_metrics = {
            "loss": loss,
            "lr": lr,
            "grad_norm": gnorm,
            **metrics,
        }
        return params, opt_state, out_metrics

    return train_step


def init_train_state(key, cfg: ArchConfig, tcfg: TrainConfig):
    params, axes = backbone.init_model(key, cfg)
    opt = init_opt_state(params, tcfg.optimizer)
    return params, opt, axes
