"""Fault-tolerance control plane: failure detection, straggler
mitigation, elastic rescale.

This is the policy layer a multi-pod deployment drives: heartbeats feed
`FailureDetector`; step-time reports feed `StragglerMonitor`; on a
failure the `FaultToleranceManager` picks the cheapest recovery action:

* 1 lost state shard  → layered DRC repair (cross-pod bytes = Eq. (3));
* ≤ n-k lost          → MDS decode from survivors;
* > n-k lost          → roll back to the last durable checkpoint;
* cluster resize      → elastic re-encode onto a new (n, k, r) stripe
                        matching the new pod topology.

All decisions are pure functions of reported state, so the layer is unit
testable without real hardware; hooks are invoked by launch/train.py.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro import obs

from .checkpoint import EncodedCheckpoint, encode_state, repair_node, restore_state

# One injectable time source threaded through the whole control plane:
# production uses the monotonic clock, tests pass a fake and every
# timeout decision becomes deterministic.
Clock = Callable[[], float]


@dataclass
class FailureDetector:
    timeout_s: float = 60.0
    clock: Clock = time.monotonic
    last_beat: dict[int, float] = field(default_factory=dict)

    def heartbeat(self, node: int, now: float | None = None):
        self.last_beat[node] = self.clock() if now is None else now
        obs.counter_add("ft.heartbeats", 1, node=str(node))

    def failed_nodes(self, now: float | None = None) -> list[int]:
        now = self.clock() if now is None else now
        return sorted(
            n for n, t in self.last_beat.items() if now - t > self.timeout_s
        )


@dataclass
class StragglerMonitor:
    """Flags pods whose step time exceeds median by `threshold`x.

    Mitigation policy mirrors the paper's §5.2 parallelization note:
    rotate relayer/target roles away from slow pods so repair (and
    checkpoint encode) work avoids stragglers.
    """

    threshold: float = 1.5
    window: int = 16
    clock: Clock = time.monotonic
    times: dict[int, list[float]] = field(default_factory=dict)
    last_seen: dict[int, float] = field(default_factory=dict)

    def report(self, pod: int, step_time: float, now: float | None = None):
        self.last_seen[pod] = self.clock() if now is None else now
        self.times.setdefault(pod, []).append(step_time)
        self.times[pod] = self.times[pod][-self.window :]
        obs.counter_add("ft.step_reports", 1, pod=str(pod))

    def stragglers(self) -> list[int]:  # check: ignore[uninstrumented-entrypoint] pure query
        if len(self.times) < 2:
            return []
        med = {p: float(np.median(t)) for p, t in self.times.items()}
        overall = float(np.median(list(med.values())))
        return sorted(p for p, m in med.items() if m > self.threshold * overall)

    def preferred_relayer_order(self, pods: list[int]) -> list[int]:
        slow = set(self.stragglers())
        return sorted(pods, key=lambda p: (p in slow, p))


@dataclass
class RecoveryAction:
    kind: str  # repair | decode | rollback | rescale
    detail: dict = field(default_factory=dict)


class FaultToleranceManager:
    def __init__(self, *, family="DRC", n=9, k=6, r=3, clock: Clock | None = None):
        self.spec = (family, n, k, r)
        self.clock = clock if clock is not None else time.monotonic
        self.detector = FailureDetector(clock=self.clock)
        self.straggler = StragglerMonitor(clock=self.clock)

    def plan_recovery(self, ckpt: EncodedCheckpoint, lost: list[int]) -> RecoveryAction:
        with obs.span("ft.plan_recovery", cat="ft", lost=len(lost)):
            n, k = ckpt.code_spec[1], ckpt.code_spec[2]
            if not lost:
                return RecoveryAction("noop")
            if len(lost) == 1:
                return RecoveryAction("repair", {"node": lost[0]})
            if len(lost) <= n - k:
                return RecoveryAction("decode", {"nodes": lost})
            return RecoveryAction("rollback", {})

    def execute(self, ckpt: EncodedCheckpoint, like, lost: list[int]):
        action = self.plan_recovery(ckpt, lost)
        with obs.span("ft.execute", cat="ft", kind=action.kind,
                      lost=len(lost)):
            if action.kind == "noop":
                state, report = restore_state(ckpt, like)
                return state, report, action
            if action.kind == "rollback":
                raise RuntimeError(
                    f"{len(lost)} failures exceed n-k; roll back to durable checkpoint"
                )
            available = set(ckpt.payloads) - set(lost)
            state, report = restore_state(ckpt, like, available=available)
            obs.counter_add("ft.recoveries", 1, kind=action.kind)
            return state, report, action

    # ------------------------------------------------------------- elastic
    def rescale(
        self, ckpt: EncodedCheckpoint, like, *, family=None, n=None, k=None, r=None
    ) -> EncodedCheckpoint:
        """Re-encode the stripe for a new cluster topology (elastic scale
        up/down): decode current state, encode with the new (n, k, r)."""
        fam, n0, k0, r0 = ckpt.code_spec
        with obs.span("ft.rescale", cat="ft", old=f"({n0},{k0},{r0})",
                      new=f"({n or n0},{k or k0},{r or r0})"):
            state, _ = restore_state(ckpt, like)
            return encode_state(
                state,
                family=family or fam,
                n=n or n0,
                k=k or k0,
                r=r or r0,
                step=ckpt.step,
            )
