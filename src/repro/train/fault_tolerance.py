"""Fault-tolerance control plane: failure detection, straggler
mitigation, elastic rescale.

This is the policy layer a multi-pod deployment drives: heartbeats feed
`FailureDetector`; step-time reports feed `StragglerMonitor`; on a
failure the `FaultToleranceManager` picks the cheapest recovery action:

* 1 lost state shard  → layered DRC repair (cross-pod bytes = Eq. (3));
* ≤ n-k lost          → MDS decode from survivors;
* > n-k lost          → roll back to the last durable checkpoint;
* cluster resize      → elastic re-encode onto a new (n, k, r) stripe
                        matching the new pod topology.

All decisions are pure functions of reported state, so the layer is unit
testable without real hardware; hooks are invoked by launch/train.py.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .checkpoint import EncodedCheckpoint, encode_state, repair_node, restore_state


@dataclass
class FailureDetector:
    timeout_s: float = 60.0
    last_beat: dict[int, float] = field(default_factory=dict)

    def heartbeat(self, node: int, now: float | None = None):
        self.last_beat[node] = time.monotonic() if now is None else now

    def failed_nodes(self, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return sorted(
            n for n, t in self.last_beat.items() if now - t > self.timeout_s
        )


@dataclass
class StragglerMonitor:
    """Flags pods whose step time exceeds median by `threshold`x.

    Mitigation policy mirrors the paper's §5.2 parallelization note:
    rotate relayer/target roles away from slow pods so repair (and
    checkpoint encode) work avoids stragglers.
    """

    threshold: float = 1.5
    window: int = 16
    times: dict[int, list[float]] = field(default_factory=dict)

    def report(self, pod: int, step_time: float):
        self.times.setdefault(pod, []).append(step_time)
        self.times[pod] = self.times[pod][-self.window :]

    def stragglers(self) -> list[int]:
        if len(self.times) < 2:
            return []
        med = {p: float(np.median(t)) for p, t in self.times.items()}
        overall = float(np.median(list(med.values())))
        return sorted(p for p, m in med.items() if m > self.threshold * overall)

    def preferred_relayer_order(self, pods: list[int]) -> list[int]:
        slow = set(self.stragglers())
        return sorted(pods, key=lambda p: (p in slow, p))


@dataclass
class RecoveryAction:
    kind: str  # repair | decode | rollback | rescale
    detail: dict = field(default_factory=dict)


class FaultToleranceManager:
    def __init__(self, *, family="DRC", n=9, k=6, r=3):
        self.spec = (family, n, k, r)
        self.detector = FailureDetector()
        self.straggler = StragglerMonitor()

    def plan_recovery(self, ckpt: EncodedCheckpoint, lost: list[int]) -> RecoveryAction:
        n, k = ckpt.code_spec[1], ckpt.code_spec[2]
        if not lost:
            return RecoveryAction("noop")
        if len(lost) == 1:
            return RecoveryAction("repair", {"node": lost[0]})
        if len(lost) <= n - k:
            return RecoveryAction("decode", {"nodes": lost})
        return RecoveryAction("rollback", {})

    def execute(self, ckpt: EncodedCheckpoint, like, lost: list[int]):
        action = self.plan_recovery(ckpt, lost)
        if action.kind == "noop":
            state, report = restore_state(ckpt, like)
            return state, report, action
        if action.kind == "rollback":
            raise RuntimeError(
                f"{len(lost)} failures exceed n-k; roll back to durable checkpoint"
            )
        available = set(ckpt.payloads) - set(lost)
        state, report = restore_state(ckpt, like, available=available)
        return state, report, action

    # ------------------------------------------------------------- elastic
    def rescale(
        self, ckpt: EncodedCheckpoint, like, *, family=None, n=None, k=None, r=None
    ) -> EncodedCheckpoint:
        """Re-encode the stripe for a new cluster topology (elastic scale
        up/down): decode current state, encode with the new (n, k, r)."""
        state, _ = restore_state(ckpt, like)
        fam, n0, k0, r0 = ckpt.code_spec
        return encode_state(
            state,
            family=family or fam,
            n=n or n0,
            k=k or k0,
            r=r or r0,
            step=ckpt.step,
        )
