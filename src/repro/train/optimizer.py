"""AdamW with configurable state dtype (bf16 states for the 100B+ MoEs).

State is a pytree mirroring params, so it inherits the same logical axis
specs (and therefore the same mesh sharding) — including the erasure-
coded checkpoint layout.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"
    grad_clip: float = 1.0


def init_opt_state(params, cfg: AdamWConfig):
    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.state_dtype]
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def opt_state_axes(param_axes):
    """Logical axis specs for the optimizer state (mirrors params)."""
    from repro.models.common import AxisSpec

    return {
        "m": param_axes,
        "v": param_axes,
        "count": AxisSpec(()),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state, lr, cfg: AdamWConfig):
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd_core(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        m_hat = m_new / (1 - cfg.b1 ** count)
        v_hat = v_new / (1 - cfg.b2 ** count)
        step = m_hat / (jnp.sqrt(v_hat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        p_new = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype)

    # NOTE: the update is a single elementwise chain per leaf; TPU fuses it
    # into one in-place pass over the (donated) buffers.  The CPU dry-run
    # backend materializes some of the f32 intermediates instead, which
    # inflates memory_analysis for the 100B+ configs (quantified in
    # EXPERIMENTS.md §Dry-run).  Chunking the update (lax.map over the
    # layer-stack axis) was tried and rejected: it breaks donation
    # aliasing and costs more than it saves.
    upd = upd_core

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, gnorm
