"""Cross-entropy over huge vocabularies.

Two implementations:

* ``sharded_xent`` — plain stable log-softmax on materialized logits
  (fine for smoke-scale and serving-path tests).
* ``vocab_parallel_xent`` — the production path: the lm-head matmul and
  the loss are fused inside a ``shard_map``; each device holds its vocab
  shard of the (tied) embedding and streams *tiles* of it against its
  tokens, keeping running (max, sum-exp, picked-logit) accumulators.
  Full (B, S, V) logits never exist; the only cross-device traffic is
  three tiny (tokens,) reductions over the model axis, and the lm-head
  gradient stays shard-local (Megatron-style vocab parallelism).  At
  command-r scale this replaces ~30 GiB of logits/all-gather traffic per
  device with ~100 MB of streamed tiles — see EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG = -1e30


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _pmax_const(x, axis_name):
    """pmax treated as a constant stabilizer.

    In the exact log-sum-exp identity lse = m* + log Σ exp(l - m*), the
    total derivative w.r.t. the stabilizer m* is identically zero, so a
    zero cotangent is exact (and sidesteps pmax's missing diff rule).
    """
    return jax.lax.pmax(x, axis_name)


def _pmax_const_fwd(x, axis_name):
    return jax.lax.pmax(x, axis_name), None


def _pmax_const_bwd(axis_name, _, g):
    # zero cotangent, re-marked as varying over the collective axis so the
    # vma type matches the primal input
    return (jax.lax.pvary(jnp.zeros_like(g), (axis_name,)),)


_pmax_const.defvjp(_pmax_const_fwd, _pmax_const_bwd)


def sharded_xent(logits: jax.Array, labels: jax.Array, real_vocab: int):
    """logits (B,S,Vp) float, labels (B,S) int32 -> mean loss (scalar).

    Vp may exceed real_vocab (padding); padded columns are masked.
    Label positions < 0 are ignored (padding tokens).
    """
    b, s, vp = logits.shape
    x = logits.astype(jnp.float32)
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, (1, 1, vp), 2)
    x = jnp.where(vocab_ids < real_vocab, x, NEG)
    m = jnp.max(x, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(x - m[..., None]), axis=-1))
    picked = jnp.sum(jnp.where(vocab_ids == labels[..., None], x, 0.0), axis=-1)
    nll = lse - picked
    valid = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)


def _tile_body(x_local, labels, v_start_global, real_vocab, logit_scale):
    """Running-reduction step over one weight tile."""

    def body(carry, wt_and_idx):
        m_prev, s_prev, picked = carry
        wt, tile_idx = wt_and_idx  # (tile, D), scalar tile index
        lt = (
            jnp.einsum(
                "nd,td->nt", x_local, wt, preferred_element_type=jnp.float32
            )
            * logit_scale
        )
        gidx = v_start_global + tile_idx * wt.shape[0] + jnp.arange(wt.shape[0])
        lt = jnp.where(gidx[None, :] < real_vocab, lt, NEG)
        m_new = jnp.maximum(m_prev, jnp.max(lt, axis=-1))
        s_new = s_prev * jnp.exp(m_prev - m_new) + jnp.sum(
            jnp.exp(lt - m_new[:, None]), axis=-1
        )
        hit = jnp.where(gidx[None, :] == labels[:, None], lt, 0.0)
        picked = picked + jnp.sum(hit, axis=-1)
        return (m_new, s_new, picked), None

    return body


def vocab_parallel_xent(
    x: jax.Array,
    w: jax.Array,
    labels: jax.Array,
    real_vocab: int,
    *,
    mesh: jax.sharding.Mesh | None = None,
    token_axes: tuple[str, ...] = ("data",),
    vocab_axis: str = "model",
    tile: int = 2048,
    logit_scale: float = 1.0,
):
    """Fused lm-head + cross-entropy.

    x (B, S, D) final hidden states; w (Vp, D) lm-head/tied embedding;
    labels (B, S) with -1 = ignore.  Returns mean nll (scalar).
    """
    b, s, d = x.shape
    n = b * s
    x2 = x.reshape(n, d)
    lab = labels.reshape(n)

    if mesh is None or mesh.size == 1 or vocab_axis not in mesh.shape:
        # single-device fallback: same tiling, no collectives
        vp = w.shape[0]
        nt = max(1, -(-vp // tile))
        pad = nt * tile - vp
        wp = jnp.pad(w, ((0, pad), (0, 0))) if pad else w
        w3 = wp.reshape(nt, tile, d)
        body = _tile_body(x2, lab, 0, real_vocab, logit_scale)
        carry = (
            jnp.full((n,), NEG, jnp.float32),
            jnp.zeros((n,), jnp.float32),
            jnp.zeros((n,), jnp.float32),
        )
        (m, se, picked), _ = jax.lax.scan(
            jax.checkpoint(body), carry, (w3, jnp.arange(nt))
        )
        nll = m + jnp.log(se) - picked
        valid = (lab >= 0).astype(jnp.float32)
        return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)

    v_shards = mesh.shape[vocab_axis]
    vp = w.shape[0]
    v_local = vp // v_shards
    from jax.sharding import PartitionSpec as P

    tok_spec = tuple(a for a in token_axes if a in mesh.shape)

    def local_fn(x2_l, lab_l, w_l):
        x2_l = x2_l.astype(w_l.dtype)
        shard = jax.lax.axis_index(vocab_axis)
        nt = max(1, -(-v_local // tile))
        pad = nt * tile - v_local
        wp = jnp.pad(w_l, ((0, pad), (0, 0))) if pad else w_l
        w3 = wp.reshape(nt, tile, d)
        nn = x2_l.shape[0]
        body = _tile_body(x2_l, lab_l, shard * v_local, real_vocab, logit_scale)
        axes = tuple(mesh.axis_names)
        carry = (
            jax.lax.pvary(jnp.full((nn,), NEG, jnp.float32), axes),
            jax.lax.pvary(jnp.zeros((nn,), jnp.float32), axes),
            jax.lax.pvary(jnp.zeros((nn,), jnp.float32), axes),
        )
        (m, se, picked), _ = jax.lax.scan(
            jax.checkpoint(body), carry, (w3, jnp.arange(nt))
        )
        # combine partial (max, sumexp, picked) across vocab shards
        m_all = _pmax_const(m, vocab_axis)
        se_all = jax.lax.psum(se * jnp.exp(m - m_all), vocab_axis)
        picked_all = jax.lax.psum(picked, vocab_axis)
        nll = m_all + jnp.log(se_all) - picked_all
        valid = (lab_l >= 0).astype(jnp.float32)
        return (
            jnp.sum(nll * valid)[None],
            jnp.sum(valid)[None],
        )

    nll_sum, valid_sum = jax.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(tok_spec), P(tok_spec), P(vocab_axis)),
        out_specs=(P(tok_spec), P(tok_spec)),
    )(x2, lab, w)
    return jnp.sum(nll_sum) / jnp.maximum(jnp.sum(valid_sum), 1.0)
