"""Erasure-coded distributed checkpointing — the paper's technique as a
first-class framework feature.

Training state (params + optimizer state) is serialized, split into k
equal *blocks*, and encoded with a chosen code (RS / MSR / DRC) into n
payloads placed on n failure domains grouped into r *racks* — in the
framework's deployment the racks are TPU pods or hosts (DESIGN.md §2).
On restore:

* all payloads present → direct (systematic) read of the k data blocks;
* one payload missing  → **layered repair** (the paper's degraded read /
  node recovery): the exact RepairPlan runs, with inner-rack vs
  cross-rack traffic accounted — DRC moves Eq. (3)-minimal bytes across
  pods;
* ≥ 2 missing, ≤ n-k    → MDS decode from any k survivors.

Payloads carry CRC32s so silent corruption degrades to the repair path.
Encode runs as one jitted, input-donated XLA program (`make_encode_step`,
built on the uint8-clean gf_matmul_jnp path) that the traced
verification layer captures and gates.
"""
from __future__ import annotations

import json
import os
import zlib
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.code_base import ErasureCode
from repro.core.codes import make_code
from repro.core.gf_jax import gf_matmul_jnp


# ------------------------------------------------------------- serialization
def state_to_bytes(state) -> tuple[bytes, list[dict]]:  # check: ignore[uninstrumented-entrypoint] pure converter
    leaves, _ = jax.tree.flatten(state)
    meta = []
    chunks = []
    for leaf in leaves:
        arr = np.asarray(leaf)
        meta.append({"shape": list(arr.shape), "dtype": str(arr.dtype)})
        chunks.append(arr.tobytes())
    return b"".join(chunks), meta


def bytes_to_state(buf: bytes, meta: list[dict], like) -> Any:  # check: ignore[uninstrumented-entrypoint] pure converter
    _, treedef = jax.tree.flatten(like)
    leaves = []
    off = 0
    for m in meta:
        dt = np.dtype(m["dtype"])
        n = int(np.prod(m["shape"])) if m["shape"] else 1
        nb = n * dt.itemsize
        arr = np.frombuffer(buf[off : off + nb], dtype=dt).reshape(m["shape"])
        leaves.append(jax.numpy.asarray(arr))
        off += nb
    return jax.tree.unflatten(treedef, leaves)


# ------------------------------------------------------------------ encoding
@dataclass
class EncodedCheckpoint:
    code_spec: tuple[str, int, int, int]
    payloads: dict[int, np.ndarray]  # node id -> (alpha, sub_bytes) uint8
    total_bytes: int
    meta: list[dict]
    step: int = 0

    @property
    def code(self) -> ErasureCode:
        return make_code(*self.code_spec)


# One compiled systematic-encode program per (code, sub_bytes) shape.
# The whole (n*alpha, sub) coded buffer is donated so XLA writes the
# parity rows into the caller's storage instead of allocating a copy —
# the traced verification layer (`repro.check.traced`) captures exactly
# this program and gates on the donation surviving into StableHLO and
# on the GF payload staying uint8 through the jaxpr.
_ENCODE_STEPS: dict[tuple[str, int], Any] = {}


def make_encode_step(code: ErasureCode, sub: int):
    """Jitted ``coded -> coded`` systematic encode with a donated input.

    ``coded`` is the full (n*alpha, sub) uint8 stripe; rows [:k*alpha]
    hold data and the step overwrites the parity rows with
    ``generator[k*alpha:] @ data`` in GF(2^8).  Uses the table-driven
    ``gf_matmul_jnp`` path, whose jaxpr keeps payload bytes uint8
    end-to-end (the log/exp reference oracle would not).
    """
    key = (repr(code), sub)
    step = _ENCODE_STEPS.get(key)
    if step is not None:
        return step
    ka = code.k * code.alpha
    gen_parity = jnp.asarray(code.generator[ka:], dtype=jnp.uint8)

    def encode(coded: jax.Array) -> jax.Array:
        parity = gf_matmul_jnp(gen_parity, coded[:ka])
        return jax.lax.dynamic_update_slice(coded, parity, (ka, 0))

    step = jax.jit(encode, donate_argnums=0)
    _ENCODE_STEPS[key] = step
    return step


def encode_state(
    state, *, family: str = "DRC", n: int = 9, k: int = 6, r: int = 3, step: int = 0
) -> EncodedCheckpoint:
    code = make_code(family, n, k, r)
    with obs.span("ckpt.encode", cat="checkpoint", family=family, n=n, k=k, r=r):
        buf, meta = state_to_bytes(state)
        total = len(buf)
        ka = code.k * code.alpha
        sub = (total + ka - 1) // ka
        sub = (sub + 127) // 128 * 128  # lane-aligned payloads for the kernel
        stripe = np.zeros((code.n * code.alpha, sub), dtype=np.uint8)
        stripe[:ka].reshape(-1)[:total] = np.frombuffer(buf, dtype=np.uint8)
        coded = np.asarray(make_encode_step(code, sub)(stripe))
        a = code.alpha
        payloads = {i: coded[i * a : (i + 1) * a] for i in range(code.n)}
        obs.counter_add("ckpt.encoded_bytes", int(coded.nbytes), family=family)
    return EncodedCheckpoint(
        code_spec=(family, n, k, r),
        payloads=payloads,
        total_bytes=total,
        meta=meta,
        step=step,
    )


@dataclass
class RestoreReport:
    mode: str  # direct | repair | decode
    repaired_nodes: list[int] = field(default_factory=list)
    cross_rack_blocks: float = 0.0
    inner_rack_blocks: float = 0.0


def restore_state(
    ckpt: EncodedCheckpoint, like, available: set[int] | None = None
) -> tuple[Any, RestoreReport]:
    code = ckpt.code
    ka = code.k * code.alpha
    if available is None:
        available = set(ckpt.payloads)
    missing = [i for i in range(code.n) if i not in available]
    with obs.span("ckpt.restore", cat="checkpoint", step=ckpt.step,
                  missing=len(missing)):
        report = RestoreReport(mode="direct")
        payloads = {i: p for i, p in ckpt.payloads.items() if i in available}

        data_nodes = list(range(code.k))
        missing_data = [i for i in data_nodes if i not in available]
        if not missing_data:
            data = np.concatenate([payloads[i] for i in data_nodes], axis=0)
        elif len(missing) == 1:
            # single-failure: the paper's layered repair (degraded read)
            f = missing[0]
            plan = code.repair_plan(f)
            repaired = plan.execute(payloads)
            t = plan.traffic_blocks()
            report = RestoreReport(
                mode="repair",
                repaired_nodes=[f],
                cross_rack_blocks=t["cross_rack_blocks"],
                inner_rack_blocks=t["inner_rack_blocks"],
            )
            payloads = dict(payloads)
            payloads[f] = repaired
            data = np.concatenate([payloads[i] for i in data_nodes], axis=0)
        else:
            if len(available) < code.k:
                raise ValueError(
                    f"unrecoverable: {len(missing)} failures > n-k = {code.n - code.k}"
                )
            chosen = dict(list(sorted(payloads.items()))[: code.k])
            data = code.decode(chosen)
            report = RestoreReport(mode="decode", repaired_nodes=missing)
        obs.counter_add("ckpt.restores", 1, mode=report.mode)
        buf = data.reshape(-1).tobytes()[: ckpt.total_bytes]
        return bytes_to_state(buf, ckpt.meta, like), report


def repair_node(ckpt: EncodedCheckpoint, failed: int) -> tuple[np.ndarray, dict]:
    """Node recovery of one payload; returns (payload, traffic stats)."""
    code = ckpt.code
    with obs.span("ckpt.repair_node", cat="checkpoint", failed=failed):
        plan = code.repair_plan(failed)
        payloads = {i: p for i, p in ckpt.payloads.items() if i != failed}
        repaired = plan.execute(payloads)
        return repaired, plan.traffic_blocks()


# ---------------------------------------------------------------------- disk
class CheckpointManager:
    """Disk-backed erasure-coded checkpoints with CRC validation.

    Layout: <dir>/step_<N>/node_<i>.bin (+ meta.json).  Each node file
    would live on a distinct host/pod in deployment; restore tolerates
    up to n-k missing or corrupt files.
    """

    def __init__(
        self, directory: str, *, family="DRC", n=9, k=6, r=3, keep: int = 3
    ):
        self.dir = directory
        self.spec = (family, n, k, r)
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _stepdir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def save(self, step: int, state) -> EncodedCheckpoint:
        ckpt = encode_state(
            state,
            family=self.spec[0],
            n=self.spec[1],
            k=self.spec[2],
            r=self.spec[3],
            step=step,
        )
        with obs.span("ckpt.save", cat="checkpoint", step=step):
            d = self._stepdir(step)
            os.makedirs(d, exist_ok=True)
            crcs = {}
            for i, payload in ckpt.payloads.items():
                raw = payload.tobytes()
                crcs[str(i)] = zlib.crc32(raw)
                with open(os.path.join(d, f"node_{i}.bin"), "wb") as f:
                    f.write(raw)
            meta = {
                "step": step,
                "code": list(ckpt.code_spec),
                "total_bytes": ckpt.total_bytes,
                "payload_shape": list(next(iter(ckpt.payloads.values())).shape),
                "crcs": crcs,
                "leaves": ckpt.meta,
            }
            with open(os.path.join(d, "meta.json"), "w") as f:
                json.dump(meta, f)
            self._gc()
        return ckpt

    def steps(self) -> list[int]:  # check: ignore[uninstrumented-entrypoint] directory scan
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.dir, name, "meta.json")
            ):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            d = self._stepdir(s)
            for f in os.listdir(d):
                os.remove(os.path.join(d, f))
            os.rmdir(d)

    def load(self, like, step: int | None = None):
        steps = self.steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        step = step if step is not None else steps[-1]
        with obs.span("ckpt.load", cat="checkpoint", step=step):
            return self._load_step(like, step)

    def _load_step(self, like, step: int):
        d = self._stepdir(step)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        shape = tuple(meta["payload_shape"])
        payloads = {}
        for i in range(meta["code"][1]):
            path = os.path.join(d, f"node_{i}.bin")
            if not os.path.exists(path):
                continue
            with open(path, "rb") as f:
                raw = f.read()
            if zlib.crc32(raw) != meta["crcs"][str(i)]:
                continue  # corrupt -> treat as failed node
            payloads[i] = np.frombuffer(raw, dtype=np.uint8).reshape(shape)
        ckpt = EncodedCheckpoint(
            code_spec=tuple(meta["code"]),
            payloads=payloads,
            total_bytes=meta["total_bytes"],
            meta=meta["leaves"],
            step=step,
        )
        state, report = restore_state(ckpt, like, available=set(payloads))
        return state, step, report
