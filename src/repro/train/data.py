"""Synthetic-token data pipeline.

Deterministic, seekable (restart-safe: the stream is a pure function of
(seed, step)), and cheap: batches are generated with a counter-based
hash so resuming from a checkpoint replays the exact token stream
without any state file.  The structure (shifted next-token labels,
ignore-index padding, optional modality side-inputs) matches what a real
loader would produce.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    batch: int = 8
    seq: int = 512


class SyntheticStream:
    """Markov-ish synthetic token stream with learnable structure."""

    def __init__(self, cfg: ArchConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data

    def batch_at(self, step: int) -> dict:  # check: ignore[uninstrumented-entrypoint] synthetic data
        rng = np.random.default_rng((self.data.seed << 20) ^ step)
        b, s = self.data.batch, self.data.seq
        v = self.cfg.vocab
        base = rng.integers(0, v, size=(b, 1), dtype=np.int32)
        drift = rng.integers(0, 17, size=(b, s), dtype=np.int32)
        toks = (base + np.cumsum(drift, axis=1)) % v
        tokens = toks.astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((b, 1), -1, np.int32)], axis=1
        )
        out = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        if self.cfg.family == "vlm" and self.cfg.vision_tokens:
            vt = self.cfg.vision_tokens
            out["vis_embeds"] = jnp.asarray(
                rng.standard_normal((b, vt, self.cfg.d_model)).astype(np.float32)
                * 0.02,
                dtype=jnp.bfloat16,
            )
            out["labels"] = jnp.concatenate(
                [jnp.full((b, vt), -1, jnp.int32), out["labels"]], axis=1
            )
        if self.cfg.family == "audio":
            out["frames"] = jnp.asarray(
                rng.standard_normal((b, self.cfg.encoder_seq, self.cfg.d_model))
                .astype(np.float32) * 0.02,
                dtype=jnp.bfloat16,
            )
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
