from .data import DataConfig, SyntheticStream
from .optimizer import AdamWConfig, adamw_update, init_opt_state, opt_state_axes
from .schedule import ScheduleConfig, learning_rate
from .train_step import TrainConfig, init_train_state, loss_fn, make_train_step
from .xent import sharded_xent

__all__ = [
    "DataConfig", "SyntheticStream", "AdamWConfig", "adamw_update",
    "init_opt_state", "opt_state_axes", "ScheduleConfig", "learning_rate",
    "TrainConfig", "init_train_state", "loss_fn", "make_train_step",
    "sharded_xent",
]
