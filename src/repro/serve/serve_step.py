"""Serving steps.

* ``prefill_step`` — full-sequence forward over the prompt (what the
  ``prefill_32k`` dry-run cell lowers): returns next-token logits.
* ``serve_step`` / ``decode_step`` — one new token against a KV cache /
  SSM state of ``kv_len`` (the ``decode_32k`` and ``long_500k`` cells).

Decode state layouts and their logical-axis specs come from
``backbone.init_decode_state`` so serving shards exactly like training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import backbone
from repro.models.config import ArchConfig


def make_prefill_step(cfg: ArchConfig, chunk: int = 512):
    def prefill_step(params, batch):
        logits, _ = backbone.forward(params, cfg, batch, chunk=chunk)
        return logits[:, -1, :]

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def serve_step(params, state, tokens, position):
        logits, state = backbone.decode_step(params, cfg, state, tokens, position)
        return logits[:, -1, :], state

    return serve_step


def sample_token(key, logits, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(
        jnp.int32
    )
