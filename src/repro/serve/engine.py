"""Minimal batched serving engine (demo/e2e scale).

Prefill at demo scale runs the decode step over the prompt inside a
lax.scan (one compiled program, cache populated token by token); the
production dry-run path lowers the full-sequence prefill separately.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import obs
from repro.models import backbone
from repro.models.config import ArchConfig
from .serve_step import make_decode_step, sample_token


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, batch: int, kv_len: int):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.kv_len = kv_len
        self.state, self.state_axes = backbone.init_decode_state(cfg, batch, kv_len)
        self._step = jax.jit(make_decode_step(cfg))
        self.position = 0

    def prefill(self, prompts: jax.Array) -> jax.Array:
        """prompts (B, S) int32; feeds them through decode steps."""
        b, s = prompts.shape
        assert b == self.batch

        def body(carry, t):
            state, _ = carry
            logits, state = self._step(
                self.params, state, prompts[:, t][:, None], t + self.position
            )
            return (state, logits.astype(jnp.float32)), None

        with obs.span("serve.prefill", cat="serve", arch=self.cfg.name,
                      batch=b, tokens=int(s), position=self.position):
            dummy = jnp.zeros((b, self.cfg.padded_vocab), jnp.float32)
            (self.state, logits), _ = jax.lax.scan(
                body, (self.state, dummy), jnp.arange(s)
            )
            self.position += s
            obs.counter_add("serve.tokens.prefill", b * int(s))
        return logits

    def generate(self, n_tokens: int, key=None, temperature: float = 0.0):
        key = key if key is not None else jax.random.key(0)
        logits = jnp.zeros((self.batch, self.cfg.padded_vocab), jnp.float32)
        last = self._last_logits if hasattr(self, "_last_logits") else None
        out = []
        tok = (
            sample_token(key, last, temperature)
            if last is not None
            else jnp.zeros((self.batch,), jnp.int32)
        )
        with obs.span("serve.generate", cat="serve", arch=self.cfg.name,
                      batch=self.batch, tokens=n_tokens,
                      temperature=temperature):
            for i in range(n_tokens):
                key, sub = jax.random.split(key)
                logits, self.state = self._step(
                    self.params, self.state, tok[:, None], self.position
                )
                tok = sample_token(sub, logits, temperature)
                out.append(tok)
                self.position += 1
            obs.counter_add("serve.tokens.decode", self.batch * n_tokens)
        self._last_logits = logits
        return jnp.stack(out, axis=1)
