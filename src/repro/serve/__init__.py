from .serve_step import make_decode_step, make_prefill_step, sample_token
from .engine import ServeEngine

__all__ = ["make_decode_step", "make_prefill_step", "sample_token", "ServeEngine"]
