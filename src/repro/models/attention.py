"""GQA attention with RoPE: training (chunked/flash-style), prefill, decode.

* Training/prefill uses a blockwise streaming softmax over KV chunks
  (O(S·chunk) memory instead of O(S²)) — the standard flash-attention
  recurrence expressed in pure JAX so it lowers on any backend; the MXU
  sees the same two batched matmuls per chunk.
* Decode consumes a KV cache laid out (batch, kv_len, kv_heads, head_dim)
  sharded over the model axis on heads (or kv_seq when kv heads don't
  divide the axis).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .common import (
    AxisSpec,
    Params,
    apply_rope,
    constrain,
    dense,
    init_dense,
    rope_angles,
    spec,
)
from .config import ArchConfig

NEG_INF = -1e30


def init_attention(key, cfg: ArchConfig, dtype):
    d, hd = cfg.d_model, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p, s = {}, {}
    p["wq"], s["wq"] = init_dense(
        kq, d, cfg.n_heads * hd, dtype, spec("embed", "heads"), bias=cfg.qkv_bias
    )
    p["wk"], s["wk"] = init_dense(
        kk, d, cfg.n_kv_heads * hd, dtype, spec("embed", "kv"), bias=cfg.qkv_bias
    )
    p["wv"], s["wv"] = init_dense(
        kv, d, cfg.n_kv_heads * hd, dtype, spec("embed", "kv"), bias=cfg.qkv_bias
    )
    p["wo"], s["wo"] = init_dense(
        ko, cfg.n_heads * hd, d, dtype, spec("heads", "embed"), bias=cfg.qkv_bias
    )
    return p, s


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _chunked_attention(q, k, v, *, causal: bool, chunk: int, q_offset: int = 0):
    """Streaming-softmax grouped attention (GQA without materializing
    repeated K/V).

    q: (B, Sq, kvH, G, D); k/v: (B, Sk, kvH, D).  Scans over Sk in chunks
    keeping running (max, sum, acc) — the flash recurrence.
    """
    b, sq, h, g, d = q.shape
    sk = k.shape[1]
    q = q * (1.0 / math.sqrt(d))
    n_chunks = max(1, sk // chunk)
    kc = k.reshape(b, n_chunks, sk // n_chunks, h, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, sk // n_chunks, h, d).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        kb, vb, idx = xs
        ck = kb.shape[1]
        logits = jnp.einsum(
            "bqhgd,bkhd->bhgqk", q, kb, preferred_element_type=jnp.float32
        )
        if causal:
            k_pos = idx * ck + jnp.arange(ck)
            mask = q_pos[:, None] >= k_pos[None, :]
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, g, sq), jnp.float32)
    a0 = jnp.zeros((b, h, g, sq, d), jnp.float32)
    # checkpoint the chunk body: the backward pass recomputes the (q,k)
    # logits instead of stacking per-chunk residuals (8 chunks × the
    # logits tensor dwarfs everything else in the block otherwise)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0), (kc, vc, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    # (B, Sq, kvH, G, D)
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)


def attention(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,
    *,
    positions: jax.Array | None = None,
    causal: bool = True,
    chunk: int = 512,
    cross_kv: tuple[jax.Array, jax.Array] | None = None,
    use_flash: bool | None = None,
) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    b, s, _ = x.shape
    hd = cfg.head_dim
    groups = cfg.n_heads // cfg.n_kv_heads
    q = _split_heads(dense(p["wq"] if "wq" in p else p, x), cfg.n_heads, hd)
    if cross_kv is None:
        k = _split_heads(dense(p["wk"], x), cfg.n_kv_heads, hd)
        v = _split_heads(dense(p["wv"], x), cfg.n_kv_heads, hd)
        if positions is None:
            positions = jnp.arange(s)[None, :]
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    else:
        k, v = cross_kv
        causal = False
    q = constrain(q, "batch", "seq", "heads", None)
    if use_flash is None:
        use_flash = jax.default_backend() == "tpu"
    if (
        use_flash
        and cross_kv is None
        and s % 256 == 0
        and k.shape[1] % 256 == 0
    ):
        # Pallas flash kernel (forward hot path on TPU); the pure-JAX
        # chunked path remains the differentiable training default.
        from repro.kernels.flash_attention import flash_attention

        out = flash_attention(q, k, v, causal=causal)
    else:
        qg = q.reshape(b, s, cfg.n_kv_heads, groups, hd)
        eff_chunk = min(chunk, k.shape[1])
        out = _chunked_attention(qg, k, v, causal=causal, chunk=eff_chunk)
    out = out.reshape(b, s, cfg.n_heads * hd)
    return dense(p["wo"], out)


def cross_kv(p: Params, cfg: ArchConfig, enc: jax.Array):
    """Precompute encoder K/V for cross-attention (whisper decoder)."""
    hd = cfg.head_dim
    k = _split_heads(dense(p["wk"], enc), cfg.n_kv_heads, hd)
    v = _split_heads(dense(p["wv"], enc), cfg.n_kv_heads, hd)
    return k, v


# ------------------------------------------------------------------ decoding
@dataclass
class KVCacheSpec:
    batch: int
    kv_len: int
    n_kv_heads: int
    head_dim: int
    dtype: object

    def zeros(self):
        shape = (self.batch, self.kv_len, self.n_kv_heads, self.head_dim)
        return {
            "k": jnp.zeros(shape, self.dtype),
            "v": jnp.zeros(shape, self.dtype),
        }

    def axes(self):
        a = spec("batch", "kv_seq", "kv", None)
        return {"k": a, "v": a}


def decode_attention(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,
    cache: Params,
    position: jax.Array,
    *,
    update_cache: bool = True,
) -> tuple[jax.Array, Params]:
    """One-token decode: x (B, 1, D), cache k/v (B, L, kvH, hd)."""
    b = x.shape[0]
    hd = cfg.head_dim
    groups = cfg.n_heads // cfg.n_kv_heads
    q = _split_heads(dense(p["wq"], x), cfg.n_heads, hd)  # (B,1,H,hd)
    k_new = _split_heads(dense(p["wk"], x), cfg.n_kv_heads, hd)
    v_new = _split_heads(dense(p["wv"], x), cfg.n_kv_heads, hd)
    pos = jnp.full((b, 1), position, jnp.int32)
    cos, sin = rope_angles(pos, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k_new = apply_rope(k_new, cos, sin)
    if update_cache:
        k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, position, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, position, 0, 0))
        cache = {"k": k, "v": v}
    else:
        k, v = cache["k"], cache["v"]
    qg = q.reshape(b, 1, cfg.n_kv_heads, groups, hd) / math.sqrt(hd)
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32
    )
    mask = jnp.arange(k.shape[1])[None, None, None, None, :] <= position
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    out = out.reshape(b, 1, cfg.n_heads * hd)
    return dense(p["wo"], out), cache
