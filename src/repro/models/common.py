"""Functional NN primitives (no framework dependency).

Parameters are nested dicts of jnp arrays; every primitive is a pair of
``init_*`` / apply functions.  Sharding is expressed through *logical
axis names* attached at init time (a parallel pytree of tuples) and
resolved to mesh `PartitionSpec`s by `repro.dist.sharding.resolve_specs`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict[str, Any]

# ---------------------------------------------------------------- logical axes
# batch/seq: activation dims; embed/ffn/heads/kv/vocab/expert: weight dims.
LOGICAL = ("batch", "seq", "embed", "ffn", "heads", "kv", "vocab", "expert")


class AxisSpec(tuple):
    """Tuple of logical axis names (or None) for one array."""


def spec(*names: str | None) -> AxisSpec:
    return AxisSpec(names)


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ---------------------------------------------------------------------- dense
def init_dense(key, d_in: int, d_out: int, dtype, axes: AxisSpec, bias=False):
    scale = 1.0 / np.sqrt(d_in)
    w = jax.random.uniform(key, (d_in, d_out), dtype, -scale, scale)
    p = {"w": w}
    s = {"w": axes}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
        s["b"] = spec(axes[-1])
    return p, s


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------- norms
def init_norm(d: int, kind: str, dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    s = {"scale": spec("embed")}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
        s["bias"] = spec("embed")
    return p, s


def apply_norm(p: Params, x: jax.Array, kind: str, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


# ----------------------------------------------------------------------- rope
def rope_angles(positions: jax.Array, head_dim: int, theta: float):
    """positions (...,) -> cos/sin (..., head_dim/2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # broadcast over heads
    s = sin[..., None, :]
    return jnp.concatenate(
        [x1 * c - x2 * s, x2 * c + x1 * s], axis=-1
    ).astype(x.dtype)


# ------------------------------------------------------------------ embedding
def init_embedding(key, vocab: int, d: int, dtype):
    w = jax.random.normal(key, (vocab, d), dtype) * 0.02
    return {"w": w}, {"w": spec("vocab", "embed")}


def embed(p: Params, ids: jax.Array) -> jax.Array:
    return p["w"][ids]


# ------------------------------------------------------------- tree utilities
def tree_stack(trees: list[Params]) -> Params:
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def stacked_specs(s: Params) -> Params:
    """Prepend the (unsharded) scan-layer axis to every spec tuple."""
    return jax.tree.map(
        lambda ax: AxisSpec((None, *ax)),
        s,
        is_leaf=lambda x: isinstance(x, AxisSpec),
    )


def param_bytes(tree: Params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def ambient_mesh():
    """The ambient abstract mesh, or None when there isn't one.

    jax >= 0.5 exposes ``jax.sharding.get_abstract_mesh()`` (set via
    use_mesh/set_mesh); older jax has no ambient-mesh context at all, so
    callers must take their single-process path.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is None:
        return None
    mesh = get()
    return None if (mesh is None or mesh.empty) else mesh


def constrain(x: jax.Array, *names: str | None) -> jax.Array:
    """Logical sharding constraint on an activation.

    Resolved through the ambient rules + mesh by
    ``repro.dist.sharding.logical_constraint``: a real
    ``with_sharding_constraint`` under a mesh, a no-op without one
    (with a one-time warning if rules were explicitly set — silent
    degradation would hide a misconfigured launch).
    """
    from repro.dist.sharding import logical_constraint

    return logical_constraint(x, names)
