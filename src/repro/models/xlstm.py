"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM is the parallelizable block: per head a (d_k × d_v) matrix memory
with exponential input gates and forget-gate decay — computed here in
the chunked form (same skeleton as SSD) so training is matmul-bound.
sLSTM keeps per-unit scalar state with a recurrent projection, so it is
inherently sequential: training scans over time (the paper's design),
decode is O(1).  Both give O(1)-per-token decode, which is what puts
xlstm-125m on the long_500k shape list.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import Params, constrain, dense, init_dense, spec
from .config import ArchConfig


# ------------------------------------------------------------------- mLSTM
def init_mlstm(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    hd = cfg.head_dim
    h = cfg.n_heads
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["wq"], s["wq"] = init_dense(ks[0], d, h * hd, dtype, spec("embed", "heads"))
    p["wk"], s["wk"] = init_dense(ks[1], d, h * hd, dtype, spec("embed", "heads"))
    p["wv"], s["wv"] = init_dense(ks[2], d, h * hd, dtype, spec("embed", "heads"))
    p["wi"], s["wi"] = init_dense(ks[3], d, h, jnp.float32, spec("embed", "state"))
    p["wf"], s["wf"] = init_dense(ks[4], d, h, jnp.float32, spec("embed", "state"))
    p["wo"], s["wo"] = init_dense(ks[5], d, d, dtype, spec("heads", "embed"))
    return p, s


def _mlstm_chunked(q, k, v, log_f, log_i, chunk: int):
    """Chunked mLSTM: C_t = f_t·C_{t-1} + i_t·(k_t ⊗ v_t); y_t = q_t·C_t.

    q/k/v (B,S,H,D); log_f/log_i (B,S,H).  Normalization follows the
    max-state stabilizer in a simplified form (denominator |q·n| + 1).
    """
    b, s, h, d = q.shape
    nc = max(1, s // chunk)
    ck = s // nc
    qr = q.reshape(b, nc, ck, h, d)
    kr = k.reshape(b, nc, ck, h, d)
    vr = v.reshape(b, nc, ck, h, d)
    lf = log_f.reshape(b, nc, ck, h)
    li = log_i.reshape(b, nc, ck, h)
    cum = jnp.cumsum(lf, axis=2)
    total = cum[:, :, -1, :]

    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # decay q<-k
    causal = jnp.tril(jnp.ones((ck, ck), bool))
    w = jnp.where(causal[None, None, :, :, None], jnp.exp(seg + li[:, :, None, :, :]), 0.0)
    scores = jnp.einsum("bnqhd,bnkhd->bnqkh", qr, kr)
    m_qkh = (scores * w).astype(q.dtype)
    y_intra = jnp.einsum(
        "bnqkh,bnkhd->bnqhd", m_qkh, vr, preferred_element_type=jnp.float32
    )

    decay_to_end = jnp.exp(total[:, :, None, :] - cum + li)
    kd = (decay_to_end[..., None] * kr).astype(q.dtype)  # (B,nc,k,H,Dk)
    chunk_state = jnp.einsum(
        "bnkhd,bnkhe->bnhde", kd, vr, preferred_element_type=jnp.float32
    )  # (B,nc,H,Dk,Dv)

    def body(c_prev, xs):
        state, tot = xs
        c_new = c_prev * jnp.exp(tot)[:, :, None, None] + state
        return c_new, c_prev

    c0 = jnp.zeros((b, h, d, d), jnp.float32)
    _, c_in = jax.lax.scan(
        body, c0, (chunk_state.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2))
    )
    c_in = c_in.transpose(1, 0, 2, 3, 4)
    qd = (qr * jnp.exp(cum)[..., None]).astype(q.dtype)
    y_inter = jnp.einsum(
        "bnqhd,bnhde->bnqhe", qd, c_in.astype(q.dtype),
        preferred_element_type=jnp.float32,
    )
    y = (y_intra + y_inter).reshape(b, s, h, d)
    norm = jnp.maximum(jnp.abs(jnp.sum(y, axis=-1, keepdims=True)), 1.0)
    return (y / norm).astype(q.dtype)


def mlstm_block(p: Params, cfg: ArchConfig, x: jax.Array, chunk: int = 256):
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = dense(p["wq"], x).reshape(b, s, h, hd)
    k = dense(p["wk"], x).reshape(b, s, h, hd) / jnp.sqrt(hd).astype(x.dtype)
    v = dense(p["wv"], x).reshape(b, s, h, hd)
    log_f = jax.nn.log_sigmoid(dense(p["wf"], x).astype(jnp.float32))
    log_i = dense(p["wi"], x).astype(jnp.float32)
    log_i = -jax.nn.softplus(-log_i)  # log sigmoid for stability
    y = _mlstm_chunked(q, k, v, log_f, log_i, chunk)
    y = constrain(y, "batch", "seq", "heads", None)
    return dense(p["wo"], y.reshape(b, s, h * hd))


def mlstm_init_state(cfg: ArchConfig, batch: int):
    h, hd = cfg.n_heads, cfg.head_dim
    return {"c": jnp.zeros((batch, h, hd, hd), jnp.float32)}


def mlstm_decode(p: Params, cfg: ArchConfig, x: jax.Array, state: Params):
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim
    q = dense(p["wq"], x).reshape(b, h, hd)
    k = dense(p["wk"], x).reshape(b, h, hd) / jnp.sqrt(hd).astype(x.dtype)
    v = dense(p["wv"], x).reshape(b, h, hd)
    f = jax.nn.sigmoid(dense(p["wf"], x).astype(jnp.float32))[:, 0, :]
    i = jax.nn.sigmoid(dense(p["wi"], x).astype(jnp.float32))[:, 0, :]
    c = state["c"] * f[:, :, None, None] + i[:, :, None, None] * jnp.einsum(
        "bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32)
    )
    y = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), c)
    norm = jnp.maximum(jnp.abs(jnp.sum(y, axis=-1, keepdims=True)), 1.0)
    y = (y / norm).reshape(b, 1, h * hd).astype(x.dtype)
    return dense(p["wo"], y), {"c": c}


# ------------------------------------------------------------------- sLSTM
def init_slstm(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["wx"], s["wx"] = init_dense(ks[0], d, 4 * d, dtype, spec("embed", "ffn"))
    p["wh"], s["wh"] = init_dense(ks[1], d, 4 * d, dtype, spec("embed", "ffn"))
    p["out"], s["out"] = init_dense(ks[2], d, d, dtype, spec("embed", "embed"))
    return p, s


def _slstm_step(p, carry, xt):
    h_prev, c_prev, n_prev = carry
    z = dense(p["wx"], xt) + dense(p["wh"], h_prev)
    zi, zf, zo, zc = jnp.split(z.astype(jnp.float32), 4, axis=-1)
    i = jnp.exp(jnp.minimum(zi, 8.0))  # exponential input gate (capped)
    f = jax.nn.sigmoid(zf)
    o = jax.nn.sigmoid(zo)
    c = f * c_prev + i * jnp.tanh(zc)
    n = f * n_prev + i
    h = (o * c / jnp.maximum(n, 1.0)).astype(xt.dtype)
    return (h, c, n), h


def slstm_block(p: Params, cfg: ArchConfig, x: jax.Array):
    b, s, d = x.shape
    h0 = jnp.zeros((b, d), x.dtype)
    c0 = jnp.zeros((b, d), jnp.float32)
    n0 = jnp.zeros((b, d), jnp.float32)

    def body(carry, xt):
        return _slstm_step(p, carry, xt)

    _, ys = jax.lax.scan(body, (h0, c0, n0), x.transpose(1, 0, 2))
    y = ys.transpose(1, 0, 2)
    return dense(p["out"], y)


def slstm_init_state(cfg: ArchConfig, batch: int, dtype):
    d = cfg.d_model
    return {
        "h": jnp.zeros((batch, d), dtype),
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
    }


def slstm_decode(p: Params, cfg: ArchConfig, x: jax.Array, state: Params):
    carry = (state["h"], state["c"], state["n"])
    carry, y = _slstm_step(p, carry, x[:, 0, :])
    h, c, n = carry
    return dense(p["out"], y)[:, None, :], {"h": h, "c": c, "n": n}
