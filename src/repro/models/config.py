"""Architecture configuration (the --arch registry's value type).

One dataclass covers all ten assigned architecture families; family-
specific knobs are optional fields.  `configs/<arch>.py` instantiates the
exact published configuration plus a `smoke()` reduction of the same
family for CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # 'expert' shards the expert dim over the model axis (EP);
    # 'ffn' shards each expert's hidden dim (TP).  EP needs
    # num_experts % model_axis == 0.
    sharding: str = "expert"


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads
    # block flavour
    parallel_block: bool = False  # command-r: attn & mlp in parallel
    mlp_act: str = "swiglu"  # swiglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False
    logit_scale: float = 1.0
    residual_scale: float = 1.0  # minicpm depth scaling
    embed_scale: float = 1.0  # minicpm mup-style embedding scale
    # moe / ssm / hybrid
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # hybrid (zamba2): one shared attention+mlp block invoked every
    # `shared_attn_every` ssm blocks (weight-tied across invocations)
    shared_attn_every: int = 0
    # xlstm: every `slstm_every`-th block is an sLSTM block
    slstm_every: int = 0
    # enc-dec (whisper): decoder cross-attends to encoder states
    encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500  # stub frame count
    # modality frontend stub: 'none' | 'audio' | 'vision'
    frontend: str = "none"
    vision_tokens: int = 0  # vlm: patch embeddings prepended to the text
    # memory/serving
    supports_long_context: bool = False  # sub-quadratic decode path
    # training numerics
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"  # bf16 for the huge MoE configs
    remat: str = "full"  # none | dots | full
    scan_layers: bool = True

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the vocab axis shards
        cleanly on any mesh (e.g. minicpm's prime-ish 122753 -> 122880)."""
        return (self.vocab + 255) // 256 * 256

    @property
    def params_billions(self) -> float:
        return self.count_params() / 1e9

    def count_params(self) -> int:
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        hd = self.head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.moe:
            ff = 3 * d * self.moe.d_ff_expert * self.moe.num_experts
            ff += d * self.moe.num_experts  # router
        elif self.mlp_act == "swiglu":
            ff = 3 * d * self.d_ff
        else:
            ff = 2 * d * self.d_ff
        per_layer = attn + ff
        if self.ssm is not None:
            d_in = d * self.ssm.expand
            ssm_per = d * (2 * d_in + 2 * self.ssm.d_state) + d_in * d
            if self.family == "ssm":
                per_layer = ssm_per + ff
            else:  # hybrid: most layers are ssm
                per_layer = ssm_per
        total = emb + self.n_layers * per_layer
        if self.encoder_decoder:
            total += self.encoder_layers * (attn + ff)  # encoder stack
            total += self.n_layers * attn  # cross attention
        if self.shared_attn_every:
            total += attn + 3 * d * self.d_ff  # one shared block
        return int(total)

    def active_params(self) -> int:
        """MoE: params touched per token (for MODEL_FLOPS = 6·N_active·D)."""
        if not self.moe:
            return self.count_params()
        d = self.d_model
        dense = dataclasses.replace(self, moe=None, d_ff=0)
        ff_active = 3 * d * self.moe.d_ff_expert * self.moe.top_k
        return dense.count_params() + self.n_layers * (
            ff_active + d * self.moe.num_experts
        )


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell: what gets lowered for the dry-run."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
