"""Feed-forward blocks: SwiGLU / GeLU and the token-choice MoE layer."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import Params, ambient_mesh, constrain, dense, init_dense, spec
from .config import ArchConfig, MoEConfig


def init_mlp(key, d: int, d_ff: int, act: str, dtype):
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["up"], s["up"] = init_dense(ks[0], d, d_ff, dtype, spec("embed", "ffn"))
    if act == "swiglu":
        p["gate"], s["gate"] = init_dense(ks[1], d, d_ff, dtype, spec("embed", "ffn"))
    p["down"], s["down"] = init_dense(ks[2], d_ff, d, dtype, spec("ffn", "embed"))
    return p, s


def mlp(p: Params, x: jax.Array, act: str) -> jax.Array:
    h = dense(p["up"], x)
    if act == "swiglu":
        h = jax.nn.silu(dense(p["gate"], x)) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", "seq", "ffn")
    return dense(p["down"], h)


# ------------------------------------------------------------------------ MoE
def init_moe(key, d: int, moe: MoEConfig, act: str, dtype):
    ks = jax.random.split(key, 4)
    e, f = moe.num_experts, moe.d_ff_expert
    lim = 1.0 / jnp.sqrt(d)

    def w(key, shape, axes):
        return jax.random.uniform(key, shape, dtype, -lim, lim), spec(*axes)

    p, s = {}, {}
    p["router"], s["router"] = init_dense(ks[0], d, e, jnp.float32, spec("embed", None))
    p["up"], s["up"] = w(ks[1], (e, d, f), ("expert", "embed", "ffn"))
    p["gate"], s["gate"] = w(ks[2], (e, d, f), ("expert", "embed", "ffn"))
    p["down"], s["down"] = w(ks[3], (e, f, d), ("expert", "ffn", "embed"))
    return p, s


def _dispatch_local(tokens, p_router, moe, k):
    """Local sort-based top-k dispatch: returns (xs (E,C,D), combine info)."""
    n, d = tokens.shape
    e = moe.num_experts
    logits = tokens.astype(jnp.float32) @ p_router  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (N, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    density = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e), axis=0)
    density_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * density_prob)

    capacity = max(8, min(int(moe.capacity_factor * n * k / e), n))
    flat_expert = expert_idx.reshape(n * k)
    flat_gate = gate_vals.reshape(n * k)
    flat_tok = jnp.repeat(jnp.arange(n), k)

    order = jnp.argsort(flat_expert)  # stable
    e_sorted = flat_expert[order]
    t_sorted = flat_tok[order]
    g_sorted = flat_gate[order]
    same = jnp.cumsum(jnp.ones_like(e_sorted), axis=0) - 1
    start = jnp.searchsorted(e_sorted, jnp.arange(e), side="left")
    pos = same - start[e_sorted]
    keep = pos < capacity
    slot = jnp.where(keep, e_sorted * capacity + pos, e * capacity)

    xs = jnp.zeros((e * capacity + 1, d), tokens.dtype)
    xs = xs.at[slot].add(tokens[t_sorted] * keep[:, None].astype(tokens.dtype))
    xs = xs[:-1].reshape(e, capacity, d)
    return xs, (slot, t_sorted, g_sorted, keep, capacity, aux)


def _combine_local(ys, info, n):
    slot, t_sorted, g_sorted, keep, capacity, _ = info
    e, _, d = ys.shape
    flat_ys = jnp.concatenate(
        [ys.reshape(e * capacity, d), jnp.zeros((1, d), ys.dtype)], axis=0
    )
    contrib = flat_ys[slot] * (g_sorted * keep).astype(ys.dtype)[:, None]
    return jnp.zeros((n, d), ys.dtype).at[t_sorted].add(contrib)


def _expert_ffn(xs, up, gate, down, act):
    h = jnp.einsum("ecd,edf->ecf", xs, up, preferred_element_type=jnp.float32)
    if act == "swiglu":
        h = (
            jax.nn.silu(
                jnp.einsum("ecd,edf->ecf", xs, gate, preferred_element_type=jnp.float32)
            )
            * h
        )
    else:
        h = jax.nn.gelu(h)
    h = h.astype(xs.dtype)
    return jnp.einsum("ecf,efd->ecd", h, down)


def moe_layer_with_loss(p: Params, cfg: ArchConfig, x: jax.Array):
    """Token-choice top-k MoE.

    Without a mesh: plain local sort-based dispatch (smoke scale).
    With a mesh: the whole layer runs in shard_map — each device routes
    and packs *its own* tokens (so no global-index gathers ever appear),
    then either

    * EP (E % model-axis == 0, e.g. dbrx 16e): all_to_all over the model
      axis ships each expert's slots to its owner, expert FFN runs on
      local experts, reverse all_to_all returns outputs (Megatron/
      Megablocks dispatch — the a2a pair is the MoE roofline signature);
    * TP (e.g. grok 8e on a 16-way axis): every device holds all experts'
      ffn *shards*; partial outputs are psum'd over the model axis.
    """
    mesh = ambient_mesh()
    if mesh is None or mesh.size == 1 or "model" not in mesh.shape:
        return _moe_single(p, cfg, x)
    return _moe_spmd(p, cfg, x, mesh)


def _moe_single(p, cfg, x):
    moe = cfg.moe
    b, s, d = x.shape
    tokens = x.reshape(b * s, d)
    xs, info = _dispatch_local(tokens, p["router"]["w"], moe, moe.top_k)
    ys = _expert_ffn(xs, p["up"], p["gate"] if cfg.mlp_act == "swiglu" else None,
                     p["down"], cfg.mlp_act)
    out = _combine_local(ys, info, tokens.shape[0])
    return out.reshape(b, s, d), info[-1]


def _moe_spmd(p, cfg, x, mesh):
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import current_rules, resolve_spec

    moe = cfg.moe
    b, s, d = x.shape
    msize = mesh.shape["model"]
    ep = moe.num_experts % msize == 0 and moe.sharding == "expert"
    rules = current_rules()
    x_spec = resolve_spec(("batch", "seq", None), x.shape, mesh, rules)
    f = moe.d_ff_expert
    # the hidden dim may shard over data in addition to / instead of the
    # expert dim (tp2d serving mode): weights then stay fully resident and
    # the down-projection's partial outputs reduce over those axes.
    extra_ffn = tuple(
        a
        for a in rules.mesh_axes("ffn")
        if a != "model" and a in mesh.shape and f % mesh.shape[a] == 0
    )
    if ep:
        ffn_axes = extra_ffn
        w_up_spec = P("model", None, ffn_axes or None)
        w_down_spec = P("model", ffn_axes or None, None)
    else:  # TP: shard each expert's hidden dim over model (+ data in tp2d)
        ffn_axes = extra_ffn
        w_up_spec = P(None, None, ("model",) + ffn_axes)
        w_down_spec = P(None, ("model",) + ffn_axes, None)
    r_spec = P()
    all_axes = tuple(mesh.axis_names)

    def _axes_of(entry):
        if entry is None:
            return ()
        return entry if isinstance(entry, tuple) else (entry,)

    token_axes = tuple(a for e in x_spec for a in _axes_of(e))
    # axes over which the expert FFN produces *partial* sums
    partial_axes = tuple(ffn_axes) if ep else ("model",) + tuple(ffn_axes)
    # partial sums are only combinable for identical tokens: gather the
    # token set over any partial axis that also shards tokens, and
    # psum_scatter the combined outputs back (Megatron TP-MLP pattern).
    gather_axes = tuple(a for a in partial_axes if a in token_axes)
    psum_axes = tuple(a for a in partial_axes if a not in token_axes)

    def local(xl, router, up, gate, down):
        bl, sl, _ = xl.shape
        tokens = xl.reshape(bl * sl, d)
        for a in gather_axes:
            tokens = jax.lax.all_gather(tokens, a, axis=0, tiled=True)
        xs, info = _dispatch_local(tokens, router, moe, moe.top_k)
        if ep:
            # a2a: (E, C, D) -> (E/m, C*m, D) expert-owner layout
            xs = jax.lax.all_to_all(xs, "model", split_axis=0, concat_axis=1, tiled=True)
            ys = _expert_ffn(xs, up, gate, down, cfg.mlp_act)
            ys = jax.lax.all_to_all(ys, "model", split_axis=1, concat_axis=0, tiled=True)
        else:
            ys = _expert_ffn(xs, up, gate, down, cfg.mlp_act)
        out = _combine_local(ys, info, tokens.shape[0])
        if psum_axes:
            out = jax.lax.psum(out, psum_axes)
        for a in reversed(gather_axes):
            out = jax.lax.psum_scatter(out, a, scatter_dimension=0, tiled=True)
        if ep and "model" not in token_axes:
            # tokens replicated over model (decode): every rank holds the
            # same combined outputs, but that can't be statically
            # inferred — reduce to prove replication
            out = jax.lax.pmean(out, "model")
        aux = info[-1]
        # vma is absent pre-0.5 (the pvary shim is the identity there)
        vma = getattr(jax.typeof(aux), "vma", frozenset())
        missing = tuple(a for a in all_axes if a not in vma)
        if missing:
            aux = jax.lax.pvary(aux, missing)
        aux = jax.lax.pmean(aux, all_axes)
        return out.reshape(bl, sl, d), aux

    gate_w = p["gate"] if cfg.mlp_act == "swiglu" else p["up"]
    out, aux = jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(x_spec, r_spec, w_up_spec, w_up_spec, w_down_spec),
        out_specs=(x_spec, P()),
    )(x, p["router"]["w"], p["up"], gate_w, p["down"])
    return out, aux


def moe_layer(p: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    return moe_layer_with_loss(p, cfg, x)[0]
