"""Unified model: init / forward / decode for all ten architectures.

Families:

* dense | moe | vlm — homogeneous decoder stack, `lax.scan` over stacked
  layer params (+ remat policy), GQA attention, SwiGLU/GeLU or MoE FFN.
  VLM prepends stub patch embeddings (`vis_embeds`) to the token stream.
* ssm (xlstm) — python-loop over mixed mLSTM/sLSTM blocks.
* hybrid (zamba2) — scanned Mamba2 segments with one weight-shared
  attention+MLP block invoked between segments.
* audio (whisper) — encoder stack over stub frame embeddings + decoder
  stack with cross-attention.

Decode state is a pytree per architecture (KV caches / SSM states) with
matching logical-axis specs so serve_step shards identically to training.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mamba2 as m2
from . import mlp as mlpm
from . import xlstm as xl
from .common import (
    AxisSpec,
    Params,
    apply_norm,
    constrain,
    embed,
    init_embedding,
    init_norm,
    spec,
    tree_stack,
    stacked_specs,
)
from .config import ArchConfig


def _dtype(cfg: ArchConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]


def _xlstm_is_slstm(cfg: ArchConfig, i: int) -> bool:
    return bool(cfg.slstm_every) and (i + 1) % cfg.slstm_every == 0


# ===================================================================== blocks
def init_decoder_block(key, cfg: ArchConfig, dtype, cross: bool = False):
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["ln1"], s["ln1"] = init_norm(cfg.d_model, cfg.norm)
    p["attn"], s["attn"] = attn.init_attention(ks[0], cfg, dtype)
    if cross:
        p["lnx"], s["lnx"] = init_norm(cfg.d_model, cfg.norm)
        p["xattn"], s["xattn"] = attn.init_attention(ks[2], cfg, dtype)
    if not cfg.parallel_block:
        p["ln2"], s["ln2"] = init_norm(cfg.d_model, cfg.norm)
    if cfg.moe:
        p["moe"], s["moe"] = mlpm.init_moe(ks[1], cfg.d_model, cfg.moe, cfg.mlp_act, dtype)
    else:
        p["mlp"], s["mlp"] = mlpm.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)
    return p, s


def decoder_block(p, cfg: ArchConfig, x, *, enc_kv=None, chunk=512):
    """Training/prefill block. Returns (out, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["ln1"], x, cfg.norm)
    a = attn.attention(p["attn"], cfg, h, chunk=chunk)
    if cfg.parallel_block:
        if cfg.moe:
            f, aux = mlpm.moe_layer_with_loss(p["moe"], cfg, h)
        else:
            f = mlpm.mlp(p["mlp"], h, cfg.mlp_act)
        out = x + (a + f) * cfg.residual_scale
    else:
        x = x + a * cfg.residual_scale
        if enc_kv is not None:
            hx = apply_norm(p["lnx"], x, cfg.norm)
            x = x + attn.attention(
                p["xattn"], cfg, hx, cross_kv=enc_kv, chunk=chunk
            ) * cfg.residual_scale
        h2 = apply_norm(p["ln2"], x, cfg.norm)
        if cfg.moe:
            f, aux = mlpm.moe_layer_with_loss(p["moe"], cfg, h2)
        else:
            f = mlpm.mlp(p["mlp"], h2, cfg.mlp_act)
        out = x + f * cfg.residual_scale
    out = constrain(out, "batch", "seq", "embed")
    return out, aux


def _remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


# =================================================================== init all
def init_model(key, cfg: ArchConfig):
    dtype = _dtype(cfg)
    keys = jax.random.split(key, cfg.n_layers + 8)
    p: Params = {}
    s: Params = {}
    p["embed"], s["embed"] = init_embedding(keys[-1], cfg.padded_vocab, cfg.d_model, dtype)
    p["ln_f"], s["ln_f"] = init_norm(cfg.d_model, cfg.norm)
    if not cfg.tie_embeddings:
        p["lm_head"], s["lm_head"] = init_embedding(
            keys[-2], cfg.padded_vocab, cfg.d_model, dtype
        )

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        blocks, bspecs = [], None
        for i in range(cfg.n_layers):
            bp, bs = init_decoder_block(keys[i], cfg, dtype)
            blocks.append(bp)
            bspecs = bs
        if cfg.scan_layers:
            p["blocks"] = tree_stack(blocks)
            s["blocks"] = stacked_specs(bspecs)
        else:
            p["blocks"] = blocks
            s["blocks"] = [bspecs] * cfg.n_layers
    elif fam == "ssm":  # xlstm
        blocks, bspecs = [], []
        for i in range(cfg.n_layers):
            if _xlstm_is_slstm(cfg, i):
                bp, bs = xl.init_slstm(keys[i], cfg, dtype)
            else:
                bp, bs = xl.init_mlstm(keys[i], cfg, dtype)
            lp, ls = init_norm(cfg.d_model, cfg.norm)
            entry = {"ln": lp, "core": bp}
            sentry = {"ln": ls, "core": bs}
            if cfg.d_ff:
                entry["ln2"], sentry["ln2"] = init_norm(cfg.d_model, cfg.norm)
                entry["mlp"], sentry["mlp"] = mlpm.init_mlp(
                    jax.random.fold_in(keys[i], 1),
                    cfg.d_model,
                    cfg.d_ff,
                    cfg.mlp_act,
                    dtype,
                )
            blocks.append(entry)
            bspecs.append(sentry)
        p["blocks"], s["blocks"] = blocks, bspecs
    elif fam == "hybrid":  # zamba2
        mams, mspecs = [], None
        for i in range(cfg.n_layers):
            lp, ls = init_norm(cfg.d_model, cfg.norm)
            bp, bs = m2.init_mamba2(keys[i], cfg, dtype)
            mams.append({"ln": lp, "core": bp})
            mspecs = {"ln": ls, "core": bs}
        seg = cfg.shared_attn_every or cfg.n_layers
        segs, rem = divmod(cfg.n_layers, seg)
        p["mamba_main"] = tree_stack(mams[: segs * seg])
        s["mamba_main"] = stacked_specs(mspecs)
        if rem:
            p["mamba_rem"] = tree_stack(mams[segs * seg :])
            s["mamba_rem"] = stacked_specs(mspecs)
        sp, ss = init_decoder_block(keys[-3], cfg, dtype)
        p["shared"], s["shared"] = sp, ss  # weight-tied across invocations
    elif fam == "audio":  # whisper
        enc, espec = [], None
        for i in range(cfg.encoder_layers):
            bp, bs = init_decoder_block(jax.random.fold_in(keys[i], 7), cfg, dtype)
            enc.append(bp)
            espec = bs
        p["encoder"] = tree_stack(enc)
        s["encoder"] = stacked_specs(espec)
        p["ln_enc"], s["ln_enc"] = init_norm(cfg.d_model, cfg.norm)
        dec, dspec = [], None
        for i in range(cfg.n_layers):
            bp, bs = init_decoder_block(keys[i], cfg, dtype, cross=True)
            dec.append(bp)
            dspec = bs
        p["blocks"] = tree_stack(dec)
        s["blocks"] = stacked_specs(dspec)
    else:
        raise ValueError(f"unknown family {fam}")
    return p, s


# ==================================================================== forward
def _logits(p, cfg: ArchConfig, x):
    w = p["embed"]["w"] if cfg.tie_embeddings else p["lm_head"]["w"]
    logits = jnp.einsum("bsd,vd->bsv", x, w) * cfg.logit_scale
    return constrain(logits, "batch", "seq", "vocab")


def _embed_inputs(p, cfg: ArchConfig, batch: dict):
    x = embed(p["embed"], batch["tokens"]) * cfg.embed_scale
    if cfg.family == "vlm" and "vis_embeds" in batch:
        x = jnp.concatenate([batch["vis_embeds"].astype(x.dtype), x], axis=1)
    return constrain(x, "batch", "seq", "embed")


def _run_encoder(p, cfg: ArchConfig, frames):
    """Whisper encoder over stub frame embeddings (conv frontend stubbed)."""
    x = frames.astype(_dtype(cfg))

    def block(xa, bp):
        ncfg = cfg
        h = apply_norm(bp["ln1"], xa, ncfg.norm)
        a = attn.attention(bp["attn"], ncfg, h, causal=False)
        xa = xa + a
        h2 = apply_norm(bp["ln2"], xa, ncfg.norm)
        xa = xa + mlpm.mlp(bp["mlp"], h2, ncfg.mlp_act)
        return xa, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(_remat(block, cfg), x, p["encoder"])
    else:
        for i in range(cfg.encoder_layers):
            bp = jax.tree.map(lambda a: a[i], p["encoder"])
            x, _ = _remat(block, cfg)(x, bp)
    return apply_norm(p["ln_enc"], x, cfg.norm)


def forward(p, cfg: ArchConfig, batch: dict, *, chunk: int = 512):
    """Full-sequence forward (training / prefill). Returns (logits, aux)."""
    x, aux = forward_hidden(p, cfg, batch, chunk=chunk)
    return _logits(p, cfg, x), aux


def lm_head_weight(p, cfg: ArchConfig):
    return p["embed"]["w"] if cfg.tie_embeddings else p["lm_head"]["w"]


def forward_hidden(p, cfg: ArchConfig, batch: dict, *, chunk: int = 512):
    """Backbone forward up to the final norm (pre-logits)."""
    x = _embed_inputs(p, cfg, batch)
    aux = jnp.zeros((), jnp.float32)
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        if cfg.scan_layers:
            def block(h, bp):
                out, a = decoder_block(bp, cfg, h, chunk=chunk)
                return out, a
            x, auxs = jax.lax.scan(_remat(block, cfg), x, p["blocks"])
            aux = aux + jnp.sum(auxs)
        else:
            for bp in p["blocks"]:
                x, a = decoder_block(bp, cfg, x, chunk=chunk)
                aux = aux + a
    elif fam == "ssm":
        def xlstm_block(idx):
            def run(bp, h_in):
                h = apply_norm(bp["ln"], h_in, cfg.norm)
                core = xl.slstm_block if _xlstm_is_slstm(cfg, idx) else xl.mlstm_block
                out = h_in + core(bp["core"], cfg, h)
                if "mlp" in bp:
                    h2 = apply_norm(bp["ln2"], out, cfg.norm)
                    out = out + mlpm.mlp(bp["mlp"], h2, cfg.mlp_act)
                return constrain(out, "batch", "seq", "embed")
            return run

        for i, bp in enumerate(p["blocks"]):
            x = _remat(xlstm_block(i), cfg)(bp, x)
    elif fam == "hybrid":
        seg = cfg.shared_attn_every or cfg.n_layers

        def mamba_step(h, bp):
            hn = apply_norm(bp["ln"], h, cfg.norm)
            h = h + m2.mamba2_block(bp["core"], cfg, hn)
            return constrain(h, "batch", "seq", "embed"), None

        main = p["mamba_main"]
        n_main = jax.tree.leaves(main)[0].shape[0]
        segs = n_main // seg
        shared_fn = _remat(
            lambda bp, h: decoder_block(bp, cfg, h, chunk=chunk), cfg
        )

        def run_mambas(h, stack, count):
            if cfg.scan_layers:
                h, _ = jax.lax.scan(_remat(mamba_step, cfg), h, stack)
                return h
            for i in range(count):
                bp = jax.tree.map(lambda a: a[i], stack)
                h, _ = _remat(mamba_step, cfg)(h, bp)
            return h

        for gi in range(segs):
            grp = jax.tree.map(lambda a: a[gi * seg : (gi + 1) * seg], main)
            x = run_mambas(x, grp, seg)
            x, a = shared_fn(p["shared"], x)
            aux = aux + a
        if "mamba_rem" in p:
            rem = p["mamba_rem"]
            x = run_mambas(x, rem, jax.tree.leaves(rem)[0].shape[0])
    elif fam == "audio":
        enc = _run_encoder(p, cfg, batch["frames"])

        def block(h, bp):
            kv = attn.cross_kv(bp["xattn"], cfg, enc)
            out, a = decoder_block(bp, cfg, h, enc_kv=kv, chunk=chunk)
            return out, a

        if cfg.scan_layers:
            x, auxs = jax.lax.scan(_remat(block, cfg), x, p["blocks"])
            aux = aux + jnp.sum(auxs)
        else:
            for i in range(cfg.n_layers):
                bp = jax.tree.map(lambda a: a[i], p["blocks"])
                x, a = _remat(block, cfg)(x, bp)
                aux = aux + a
    else:
        raise ValueError(fam)

    x = apply_norm(p["ln_f"], x, cfg.norm)
    return x, aux


# ===================================================================== decode
def init_decode_state(cfg: ArchConfig, batch: int, kv_len: int):
    """Per-architecture decode state (+ logical axis specs)."""
    dtype = _dtype(cfg)
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "audio"):
        kspec = attn.KVCacheSpec(batch, kv_len, cfg.n_kv_heads, cfg.head_dim, dtype)
        def stack_cache():
            c = kspec.zeros()
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), c
            )
        state = {"kv": stack_cache()}
        axes = {"kv": jax.tree.map(
            lambda s_: AxisSpec((None, *s_)), kspec.axes(),
            is_leaf=lambda x: isinstance(x, AxisSpec),
        )}
        if fam == "audio":
            state["enc"] = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model), dtype)
            axes["enc"] = spec("batch", None, "embed")
        return state, axes
    if fam == "ssm":
        states, axes = [], []
        for i in range(cfg.n_layers):
            if cfg.slstm_every and (i + 1) % cfg.slstm_every == 0:
                states.append(xl.slstm_init_state(cfg, batch, dtype))
                axes.append({"h": spec("batch", "embed"), "c": spec("batch", "embed"),
                             "n": spec("batch", "embed")})
            else:
                states.append(xl.mlstm_init_state(cfg, batch))
                axes.append({"c": spec("batch", "heads", None, None)})
        return {"blocks": states}, {"blocks": axes}
    if fam == "hybrid":
        seg = cfg.shared_attn_every or cfg.n_layers
        n_shared = cfg.n_layers // seg
        mstate = m2.mamba2_init_state(cfg, batch)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), mstate
        )
        maxes = jax.tree.map(
            lambda s_: AxisSpec((None, *s_)), m2.mamba2_state_axes(),
            is_leaf=lambda x: isinstance(x, AxisSpec),
        )
        kspec = attn.KVCacheSpec(batch, kv_len, cfg.n_kv_heads, cfg.head_dim, dtype)
        shared_kv = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_shared, *a.shape)), kspec.zeros()
        )
        kaxes = jax.tree.map(
            lambda s_: AxisSpec((None, *s_)), kspec.axes(),
            is_leaf=lambda x: isinstance(x, AxisSpec),
        )
        return {"mamba": stacked, "shared_kv": shared_kv}, {
            "mamba": maxes,
            "shared_kv": kaxes,
        }
    raise ValueError(fam)


def decode_step(p, cfg: ArchConfig, state, tokens, position):
    """One-token decode. tokens (B, 1) int32; returns (logits, new state)."""
    x = embed(p["embed"], tokens) * cfg.embed_scale
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        def block(h, xs):
            bp, cache = xs
            hn = apply_norm(bp["ln1"], h, cfg.norm)
            a, cache = attn.decode_attention(bp["attn"], cfg, hn, cache, position)
            if cfg.parallel_block:
                f = (
                    mlpm.moe_layer(bp["moe"], cfg, hn)
                    if cfg.moe
                    else mlpm.mlp(bp["mlp"], hn, cfg.mlp_act)
                )
                h = h + (a + f) * cfg.residual_scale
            else:
                h = h + a * cfg.residual_scale
                h2 = apply_norm(bp["ln2"], h, cfg.norm)
                f = (
                    mlpm.moe_layer(bp["moe"], cfg, h2)
                    if cfg.moe
                    else mlpm.mlp(bp["mlp"], h2, cfg.mlp_act)
                )
                h = h + f * cfg.residual_scale
            return h, cache

        if cfg.scan_layers:
            x, new_kv = jax.lax.scan(block, x, (p["blocks"], state["kv"]))
        else:
            parts = []
            blocks = p["blocks"]
            stacked = isinstance(blocks, dict)
            for i in range(cfg.n_layers):
                bp = (
                    jax.tree.map(lambda a: a[i], blocks) if stacked else blocks[i]
                )
                cache = jax.tree.map(lambda a: a[i], state["kv"])
                x, cache = block(x, (bp, cache))
                parts.append(cache)
            new_kv = jax.tree.map(lambda *xs: jnp.stack(xs), *parts)
        state = {**state, "kv": new_kv}
    elif fam == "audio":
        enc = state["enc"]

        def block(h, xs):
            bp, cache = xs
            hn = apply_norm(bp["ln1"], h, cfg.norm)
            a, cache = attn.decode_attention(bp["attn"], cfg, hn, cache, position)
            h = h + a
            hx = apply_norm(bp["lnx"], h, cfg.norm)
            kv = attn.cross_kv(bp["xattn"], cfg, enc)
            h = h + attn.attention(bp["xattn"], cfg, hx, cross_kv=kv)
            h2 = apply_norm(bp["ln2"], h, cfg.norm)
            h = h + mlpm.mlp(bp["mlp"], h2, cfg.mlp_act)
            return h, cache

        if cfg.scan_layers:
            x, new_kv = jax.lax.scan(block, x, (p["blocks"], state["kv"]))
        else:
            parts = []
            for i in range(cfg.n_layers):
                bp = jax.tree.map(lambda a: a[i], p["blocks"])
                cache = jax.tree.map(lambda a: a[i], state["kv"])
                x, cache = block(x, (bp, cache))
                parts.append(cache)
            new_kv = jax.tree.map(lambda *xs: jnp.stack(xs), *parts)
        state = {**state, "kv": new_kv}
    elif fam == "ssm":
        new_states = []
        for i, (bp, st) in enumerate(zip(p["blocks"], state["blocks"])):
            h = apply_norm(bp["ln"], x, cfg.norm)
            if _xlstm_is_slstm(cfg, i):
                y, st = xl.slstm_decode(bp["core"], cfg, h, st)
            else:
                y, st = xl.mlstm_decode(bp["core"], cfg, h, st)
            x = x + y
            if "mlp" in bp:
                h2 = apply_norm(bp["ln2"], x, cfg.norm)
                x = x + mlpm.mlp(bp["mlp"], h2, cfg.mlp_act)
            new_states.append(st)
        state = {"blocks": new_states}
    elif fam == "hybrid":
        seg = cfg.shared_attn_every or cfg.n_layers
        n_main = jax.tree.leaves(p["mamba_main"])[0].shape[0]
        segs = n_main // seg

        def mamba_step(h, xs):
            bp, st = xs
            hn = apply_norm(bp["ln"], h, cfg.norm)
            y, st = m2.mamba2_decode(bp["core"], cfg, hn, st)
            return h + y, st

        def run_mamba_decode(h, grp, mst, count):
            if cfg.scan_layers:
                return jax.lax.scan(mamba_step, h, (grp, mst))
            outs = []
            for i in range(count):
                bp = jax.tree.map(lambda a: a[i], grp)
                st = jax.tree.map(lambda a: a[i], mst)
                h, st = mamba_step(h, (bp, st))
                outs.append(st)
            return h, jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

        new_mamba_parts = []
        new_kv_parts = []
        for gi in range(segs):
            grp = jax.tree.map(lambda a: a[gi * seg : (gi + 1) * seg], p["mamba_main"])
            mst = jax.tree.map(
                lambda a: a[gi * seg : (gi + 1) * seg], state["mamba"]
            )
            x, mst = run_mamba_decode(x, grp, mst, seg)
            new_mamba_parts.append(mst)
            cache = jax.tree.map(lambda a: a[gi], state["shared_kv"])
            hn = apply_norm(p["shared"]["ln1"], x, cfg.norm)
            a, cache = attn.decode_attention(p["shared"]["attn"], cfg, hn, cache, position)
            x = x + a
            h2 = apply_norm(p["shared"]["ln2"], x, cfg.norm)
            x = x + mlpm.mlp(p["shared"]["mlp"], h2, cfg.mlp_act)
            new_kv_parts.append(cache)
        if "mamba_rem" in p:
            mst = jax.tree.map(lambda a: a[segs * seg :], state["mamba"])
            rem_n = jax.tree.leaves(p["mamba_rem"])[0].shape[0]
            x, mst = run_mamba_decode(x, p["mamba_rem"], mst, rem_n)
            new_mamba_parts.append(mst)
        state = {
            "mamba": jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *new_mamba_parts
            ),
            "shared_kv": jax.tree.map(
                lambda *xs: jnp.stack(xs, axis=0), *new_kv_parts
            ),
        }
    else:
        raise ValueError(fam)

    x = apply_norm(p["ln_f"], x, cfg.norm)
    return _logits(p, cfg, x), state
