"""Mamba2 (SSD) block — chunked state-space recurrence (zamba2 backbone).

Training runs the SSD chunked algorithm: intra-chunk attention-like
matmuls plus an inter-chunk scan over the (heads, head_dim, d_state)
state — matmul-heavy and O(S·chunk) memory.  Decode carries the state
explicitly and costs O(1) per token (the sub-quadratic long-context
path that qualifies zamba2/xlstm for the long_500k shape).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import Params, constrain, dense, init_dense, spec
from .config import ArchConfig


def init_mamba2(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    ssm = cfg.ssm
    d_in = d * ssm.expand
    n_heads = d_in // ssm.head_dim
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    # fused input projection: [x, z, B, C, dt]
    p["in_xz"], s["in_xz"] = init_dense(ks[0], d, 2 * d_in, dtype, spec("embed", "ffn"))
    p["in_bc"], s["in_bc"] = init_dense(
        ks[1], d, 2 * ssm.d_state, dtype, spec("embed", None)
    )
    p["in_dt"], s["in_dt"] = init_dense(ks[2], d, n_heads, dtype, spec("embed", "state"))
    p["conv"] = jax.random.normal(ks[3], (ssm.d_conv, d_in), dtype) * 0.02
    s["conv"] = spec(None, "ffn")
    p["a_log"] = jnp.zeros((n_heads,), jnp.float32)
    s["a_log"] = spec("state")
    p["d_skip"] = jnp.ones((n_heads,), jnp.float32)
    s["d_skip"] = spec("state")
    p["out"], s["out"] = init_dense(ks[4], d_in, d, dtype, spec("ffn", "embed"))
    return p, s


def _conv1d(x, w):
    """Causal depthwise conv: x (B,S,C), w (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out


def _ssd_chunked(x, dt, a, b, c, chunk: int):
    """SSD recurrence: h_t = exp(a·dt_t)·h_{t-1} + dt_t·(b_t ⊗ x_t).

    x (B,S,H,P), dt (B,S,H), a (H) negative, b/c (B,S,N).
    Returns y (B,S,H,P) with y_t = c_t · h_t.
    """
    bsz, s, h, pdim = x.shape
    n = b.shape[-1]
    nc = max(1, s // chunk)
    ck = s // nc
    xr = x.reshape(bsz, nc, ck, h, pdim)
    dtr = dt.reshape(bsz, nc, ck, h)
    br = b.reshape(bsz, nc, ck, n)
    cr = c.reshape(bsz, nc, ck, n)

    la = dtr * a[None, None, None, :]  # log decay per step (negative)
    cum = jnp.cumsum(la, axis=2)  # (B,nc,ck,H) within-chunk cumulative
    total = cum[:, :, -1, :]  # (B,nc,H)

    # intra-chunk (causal "attention" with decay weights).  Contraction
    # order is controlled manually: the (q,k,H) decay tensor is built
    # once in bf16 and contracted against x in a single k-reduction —
    # naive 4-operand einsum would materialize a (q,k,H,P) monster.
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,q,k,H)
    causal = jnp.tril(jnp.ones((ck, ck), bool))
    w = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bnqs,bnks->bnqk", cr, br)  # (B,nc,q,k)
    m_qkh = (scores[..., None] * w * dtr[:, :, None, :, :]).astype(x.dtype)
    y_intra = jnp.einsum(
        "bnqkh,bnkhp->bnqhp", m_qkh, xr, preferred_element_type=jnp.float32
    )

    # chunk-final states: sum_k exp(total - cum_k)·dt_k·(b_k ⊗ x_k)
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)  # (B,nc,ck,H)
    dbx = ((decay_to_end * dtr)[..., None] * xr).astype(x.dtype)  # (B,nc,k,H,P)
    chunk_state = jnp.einsum(
        "bnks,bnkhp->bnhsp", br.astype(x.dtype), dbx,
        preferred_element_type=jnp.float32,
    )  # (B,nc,H,N,P)

    # inter-chunk scan
    def body(h_prev, xs):
        state, tot = xs  # (B,H,N,P), (B,H)
        h_new = h_prev * jnp.exp(tot)[:, :, None, None] + state
        return h_new, h_prev

    h0 = jnp.zeros((bsz, h, n, pdim), jnp.float32)
    _, h_in = jax.lax.scan(
        body,
        h0,
        (chunk_state.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    h_in = h_in.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P): state entering chunk

    # inter-chunk contribution: y += c_q · exp(cum_q) · h_in
    y_inter = jnp.einsum(
        "bnqs,bnhsp->bnqhp", cr.astype(x.dtype), h_in.astype(x.dtype),
        preferred_element_type=jnp.float32,
    ) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(bsz, s, h, pdim)
    return y.astype(x.dtype)


def mamba2_block(p: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    ssm = cfg.ssm
    d_in = cfg.d_model * ssm.expand
    n_heads = d_in // ssm.head_dim
    bsz, s, _ = x.shape
    xz = dense(p["in_xz"], x)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = _conv1d(xi, p["conv"])
    xi = jax.nn.silu(xi)
    bc = dense(p["in_bc"], x).astype(jnp.float32)
    b, c = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dense(p["in_dt"], x).astype(jnp.float32))  # (B,S,H)
    a = -jnp.exp(p["a_log"])  # (H,)
    xh = xi.reshape(bsz, s, n_heads, ssm.head_dim)
    y = _ssd_chunked(xh, dt, a, b, c, ssm.chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = constrain(y, "batch", "seq", "ffn")
    return dense(p["out"], y)


# ------------------------------------------------------------------ decoding
def mamba2_init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    ssm = cfg.ssm
    d_in = cfg.d_model * ssm.expand
    h = d_in // ssm.head_dim
    return {
        "h": jnp.zeros((batch, h, ssm.d_state, ssm.head_dim), dtype),
        "conv": jnp.zeros((batch, ssm.d_conv - 1, d_in), dtype),
    }


def mamba2_state_axes():
    return {"h": spec("batch", "state", None, None), "conv": spec("batch", None, "ffn")}


def mamba2_decode(p: Params, cfg: ArchConfig, x: jax.Array, state: Params):
    """One token: x (B,1,D) -> (y, new_state). O(1) in context length."""
    ssm = cfg.ssm
    d_in = cfg.d_model * ssm.expand
    n_heads = d_in // ssm.head_dim
    bsz = x.shape[0]
    xz = dense(p["in_xz"], x)
    xi, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([state["conv"], xi.astype(state["conv"].dtype)], axis=1)
    xi = jnp.einsum("bkc,kc->bc", window, p["conv"].astype(window.dtype))[:, None, :]
    new_conv = window[:, 1:, :]
    xi = jax.nn.silu(xi)
    bc = dense(p["in_bc"], x).astype(jnp.float32)
    b, c = jnp.split(bc, 2, axis=-1)  # (B,1,N)
    dt = jax.nn.softplus(dense(p["in_dt"], x).astype(jnp.float32))  # (B,1,H)
    a = -jnp.exp(p["a_log"])
    xh = xi.reshape(bsz, n_heads, ssm.head_dim).astype(jnp.float32)
    decay = jnp.exp(dt[:, 0, :, None, None] * a[None, :, None, None])
    update = jnp.einsum(
        "bh,bs,bhp->bhsp", dt[:, 0, :], b[:, 0, :], xh
    )
    h_new = state["h"] * decay + update
    y = jnp.einsum("bs,bhsp->bhp", c[:, 0, :], h_new)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return dense(p["out"], y), {"h": h_new, "conv": new_conv}
