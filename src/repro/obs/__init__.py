"""``repro.obs`` — span tracing + metrics for the repair pipeline.

The measurement substrate every layer shares (paper §6 methodology):
the repair-plan executor, the cluster simulator, the GF(256) kernels and
the benchmark drivers all emit the *same* stage schema —

    disk → node_encode → inner → relayer_encode → cross → decode → write

— as spans, plus typed counters (bytes inner-/cross-rack, GF multiply
bytes, units per relayer) and gauges (achieved GB/s), so simulated and
measured runs are directly comparable in one Chrome trace.

Usage::

    from repro import obs

    with obs.tracing("my-run") as tr:
        code.repair(0, payloads)            # library code self-instruments
    obs.write_chrome_trace(tr, "trace.json")   # chrome://tracing
    print(obs.summary(tr))

All module-level helpers (`span`, `counter_add`, `gauge_set`,
`record_span`) are no-ops costing one global read when no tracer is
active — instrumented hot paths pay nothing measurable while tracing
is off.
"""
from .export import (
    spans_from_chrome,
    summary,
    to_chrome_trace,
    write_chrome_trace,
    write_summary,
)
from .metrics import CounterEvent, MetricSet
from .tracer import (
    NULL_SPAN,
    Span,
    Tracer,
    counter_add,
    current,
    enabled,
    gauge_set,
    record_span,
    span,
    tracing,
)

# Canonical stage-span names: keep in lock-step with
# repro.storage.simulator.StageTimes.as_dict().
STAGE_NAMES = (
    "disk", "node_encode", "inner", "relayer_encode", "cross", "decode",
    "write",
)

__all__ = [
    "CounterEvent", "MetricSet", "NULL_SPAN", "STAGE_NAMES", "Span",
    "Tracer", "counter_add", "current", "enabled", "gauge_set",
    "record_span", "span", "spans_from_chrome", "summary", "to_chrome_trace",
    "tracing", "write_chrome_trace", "write_summary",
]
