"""Exporters for ``repro.obs``: JSON summaries + Chrome ``trace_event``.

Chrome format (load in chrome://tracing or https://ui.perfetto.dev):

* each `Span` becomes a complete event (``"ph": "X"``) with ``ts``/``dur``
  in microseconds; the span's track maps to a stable integer ``tid``
  whose human name is emitted as ``thread_name`` metadata;
* span ids/parent ids and user attrs ride in ``args`` so the export is
  lossless — `spans_from_chrome` rebuilds the span list for round-trip
  tests and offline analysis;
* journalled counter updates become counter events (``"ph": "C"``), one
  track per counter name, one series per label set.

The JSON summary aggregates per span name (count/total/mean/max) and
dumps final counter/gauge values — the compact artifact benchmarks
persist next to their CSV results.
"""
from __future__ import annotations

import json
from typing import Any

from .metrics import label_str
from .tracer import Span, Tracer

_PID = 0


def _track_ids(tracer: Tracer) -> dict[str, int]:
    tracks: dict[str, int] = {}
    for s in tracer.spans:
        tracks.setdefault(s.track, len(tracks))
    return tracks


def to_chrome_trace(tracer: Tracer) -> dict[str, Any]:
    tracks = _track_ids(tracer)
    events: list[dict[str, Any]] = [
        {"ph": "M", "pid": _PID, "name": "process_name",
         "args": {"name": tracer.name}},
    ]
    for track, tid in tracks.items():
        events.append({"ph": "M", "pid": _PID, "tid": tid,
                       "name": "thread_name", "args": {"name": track}})
    for s in tracer.spans:
        args = dict(s.attrs)
        args["span_id"] = s.span_id
        if s.parent_id is not None:
            args["parent_id"] = s.parent_id
        events.append({
            "ph": "X", "pid": _PID, "tid": tracks[s.track],
            "name": s.name, "cat": s.cat or "default",
            "ts": s.start_us, "dur": s.dur_us, "args": args,
        })
    ctid = len(tracks)
    for ev in tracer.metrics.counter_events:
        series = label_str(ev.labels) or "value"
        events.append({
            "ph": "C", "pid": _PID, "tid": ctid, "name": ev.name,
            "ts": ev.ts_us, "args": {series: ev.value},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def spans_from_chrome(obj: dict[str, Any]) -> list[Span]:
    """Inverse of `to_chrome_trace` for the "X" events (round-trip tests)."""
    names: dict[int, str] = {}
    for ev in obj["traceEvents"]:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            names[ev["tid"]] = ev["args"]["name"]
    spans: list[Span] = []
    for ev in obj["traceEvents"]:
        if ev.get("ph") != "X":
            continue
        args = dict(ev.get("args", {}))
        span_id = args.pop("span_id")
        parent_id = args.pop("parent_id", None)
        cat = ev.get("cat", "")
        spans.append(Span(
            span_id, parent_id, ev["name"],
            "" if cat == "default" else cat,
            names.get(ev["tid"], str(ev["tid"])),
            ev["ts"], ev["dur"], args,
        ))
    spans.sort(key=lambda s: s.span_id)
    return spans


def summary(tracer: Tracer) -> dict[str, Any]:
    by_name: dict[str, dict[str, Any]] = {}  # count/*_us floats + "cat" str
    for s in tracer.spans:
        agg = by_name.setdefault(s.name, {
            "count": 0, "total_us": 0.0, "max_us": 0.0, "cat": s.cat
        })
        agg["count"] += 1
        agg["total_us"] += s.dur_us
        agg["max_us"] = max(agg["max_us"], s.dur_us)
    for agg in by_name.values():
        agg["mean_us"] = agg["total_us"] / agg["count"]
    out: dict[str, Any] = {"trace": tracer.name, "spans": by_name}
    out.update(tracer.metrics.as_dict())
    return out


def write_chrome_trace(tracer: Tracer, path: str) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(tracer), f, indent=1)
    return path


def write_summary(tracer: Tracer, path: str) -> str:
    with open(path, "w") as f:
        json.dump(summary(tracer), f, indent=1, sort_keys=True)
    return path
