"""Typed counters and gauges for ``repro.obs``.

Two metric kinds, both labelled:

* **Counter** — monotonically accumulating (``counter_add``): bytes
  moved inner- vs cross-rack, GF multiply bytes, units sent per relayer.
* **Gauge** — last-write-wins (``gauge_set``): achieved GB/s of a kernel
  invocation, recovery throughput of a simulated run.

A metric instance is keyed by ``(name, sorted labels)``.  Every counter
update is also journalled with a timestamp so the Chrome-trace exporter
can render counter tracks (``"ph": "C"``) alongside the spans.

Aggregation rules used by the summary exporter and ``counter_value``:
counters sum across label sets of the same name; gauges never aggregate
(each label set reports its own last value).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable

LabelKey = tuple[tuple[str, str], ...]


def _key(labels: dict[str, str]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def label_str(key: LabelKey) -> str:
    return ",".join(f"{k}={v}" for k, v in key) if key else ""


@dataclass(frozen=True)
class CounterEvent:
    """One journalled counter update (cumulative value after the add)."""

    ts_us: float
    name: str
    labels: LabelKey
    value: float


class MetricSet:
    """Thread-safe counter/gauge store attached to one Tracer."""

    def __init__(self, clock_us: Callable[[], float]):
        self._clock_us = clock_us
        self._lock = threading.Lock()
        self.counters: dict[tuple[str, LabelKey], float] = {}
        self.gauges: dict[tuple[str, LabelKey], float] = {}
        self.counter_events: list[CounterEvent] = []

    # ------------------------------------------------------------ counters
    def counter_add(self, name: str, value: float, **labels: str) -> None:
        if value < 0:
            raise ValueError(f"counter {name!r} add must be >= 0, got {value}")
        k = (name, _key(labels))
        with self._lock:
            new = self.counters.get(k, 0.0) + value
            self.counters[k] = new
            self.counter_events.append(
                CounterEvent(self._clock_us(), name, k[1], new)
            )

    def counter_value(self, name: str, **labels: str) -> float:
        """Current value; with no labels given, sums all label sets."""
        with self._lock:
            if labels:
                return self.counters.get((name, _key(labels)), 0.0)
            return sum(v for (n, _), v in self.counters.items() if n == name)

    # -------------------------------------------------------------- gauges
    def gauge_set(self, name: str, value: float, **labels: str) -> None:
        with self._lock:
            self.gauges[(name, _key(labels))] = float(value)

    def gauge_value(self, name: str, **labels: str) -> float | None:
        with self._lock:
            return self.gauges.get((name, _key(labels)))

    # ------------------------------------------------------------- export
    def as_dict(self) -> dict[str, dict[str, dict[str, float]]]:
        """{"counters": {name: {label_str: value}}, "gauges": {...}}."""
        with self._lock:
            out: dict[str, dict[str, dict[str, float]]] = {
                "counters": {}, "gauges": {}
            }
            for (name, key), v in sorted(self.counters.items()):
                out["counters"].setdefault(name, {})[label_str(key)] = v
            for (name, key), v in sorted(self.gauges.items()):
                out["gauges"].setdefault(name, {})[label_str(key)] = v
            return out
