"""Span tracer: nested, monotonic, thread-aware — the timing half of
``repro.obs``.

A `Tracer` collects `Span` records on a single monotonic timebase
(microseconds since the tracer's epoch).  Spans come from two sources:

* **measured** — ``tracer.span(name)`` context managers wrap real work
  and record wall-clock via ``time.monotonic_ns``; nesting is tracked
  per-thread, so concurrent threads produce independent span stacks that
  land on separate tracks;
* **synthetic** — ``tracer.record_span(name, dur_s, ...)`` injects a
  span with an explicit duration (and optionally an explicit start) so
  *simulated* stage times (repro.storage.simulator) and externally-timed
  intervals (kernel dispatch) share the same schema and trace files as
  measured spans.

Activation is process-global (one tracer at a time, activations nest)
while the span *stack* is thread-local — so library code (repair
execution, the simulator, the GF kernels) records spans and counters
without plumbing a tracer argument through every call, and worker
threads spawned under an active tracer record into it too.  When no
tracer is active every module-level helper is a no-op that costs one
global read.
"""
from __future__ import annotations

import contextlib
import itertools
import threading
import time
from typing import Any, Iterator

from .metrics import MetricSet

_active: "Tracer | None" = None
_active_lock = threading.Lock()


class Span:
    """One timed (or synthetic) interval.  Times are µs since tracer epoch."""

    __slots__ = ("span_id", "parent_id", "name", "cat", "track",
                 "start_us", "dur_us", "attrs")

    def __init__(self, span_id: int, parent_id: int | None, name: str,
                 cat: str, track: str, start_us: float, dur_us: float,
                 attrs: dict[str, Any]):
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.cat = cat
        self.track = track
        self.start_us = start_us
        self.dur_us = dur_us
        self.attrs = attrs

    def set_attr(self, key: str, value: Any) -> None:
        self.attrs[key] = value

    @property
    def dur_s(self) -> float:
        return self.dur_us / 1e6

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, cat={self.cat!r}, track={self.track!r}, "
                f"start={self.start_us:.1f}us, dur={self.dur_us:.1f}us)")


class _NullSpan(contextlib.AbstractContextManager["_NullSpan"]):
    """Shared no-op stand-in returned while tracing is disabled."""

    __slots__ = ()

    def set_attr(self, key: str, value: Any) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: object) -> None:
        pass


NULL_SPAN = _NullSpan()


class Tracer:
    """Collects spans + metrics for one traced run.  Thread-safe."""

    def __init__(self, name: str = "trace"):
        self.name = name
        self._prev: Tracer | None = None  # tracer shadowed by this activation
        self.epoch_ns = time.monotonic_ns()
        self.spans: list[Span] = []
        self.metrics = MetricSet(clock_us=self.now_us)
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        self._tls = threading.local()  # per-thread span stack
        self._cursors: dict[str, float] = {}  # synthetic-track layout cursors
        self._seq = itertools.count(1)

    # ------------------------------------------------------------ timebase
    def now_us(self) -> float:
        return (time.monotonic_ns() - self.epoch_ns) / 1e3

    def next_seq(self) -> int:
        """Monotonic sequence number (e.g. to name one track per operation)."""
        return next(self._seq)

    # ------------------------------------------------------------- spans
    def _stack(self) -> list[Span]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def current_span(self) -> Span | None:
        stack = self._stack()
        return stack[-1] if stack else None

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "", **attrs: Any) -> Iterator[Span]:
        """Measured span: times the enclosed block, nests per-thread."""
        stack = self._stack()
        parent = stack[-1].span_id if stack else None
        s = Span(next(self._ids), parent, name, cat,
                 threading.current_thread().name, self.now_us(), 0.0, attrs)
        stack.append(s)
        try:
            yield s
        finally:
            s.dur_us = self.now_us() - s.start_us
            stack.pop()
            with self._lock:
                self.spans.append(s)

    def record_span(self, name: str, dur_s: float, *, cat: str = "",
                    track: str | None = None, at_s: float | None = None,
                    **attrs: Any) -> Span:
        """Synthetic span with an externally-supplied duration.

        ``at_s`` places the span at an explicit start offset (seconds on
        the tracer timeline).  Without it, spans on the same ``track``
        are laid out back-to-back from that track's cursor — this is how
        the simulator renders its sequential stage pipeline; tracks
        default to the calling thread (span ends "now", i.e. it times an
        interval that just finished).
        """
        cur = self.current_span()
        parent = cur.span_id if cur is not None else None
        dur_us = dur_s * 1e6
        if at_s is not None:
            start_us = at_s * 1e6
            track = track or threading.current_thread().name
        elif track is not None:
            with self._lock:
                start_us = self._cursors.get(track, 0.0)
                self._cursors[track] = start_us + dur_us
        else:
            track = threading.current_thread().name
            start_us = self.now_us() - dur_us
        s = Span(next(self._ids), parent, name, cat, track, start_us,
                 dur_us, attrs)
        with self._lock:
            self.spans.append(s)
        return s

    # ------------------------------------------------------------ metrics
    def counter_add(self, name: str, value: float, **labels: str) -> None:
        self.metrics.counter_add(name, value, **labels)

    def gauge_set(self, name: str, value: float, **labels: str) -> None:
        self.metrics.gauge_set(name, value, **labels)

    def counter_value(self, name: str, **labels: str) -> float:
        return self.metrics.counter_value(name, **labels)

    # ------------------------------------------------------------ queries
    def spans_named(self, name: str) -> list[Span]:
        return [s for s in self.spans if s.name == name]

    def spans_in_cat(self, cat: str) -> list[Span]:
        return [s for s in self.spans if s.cat == cat]

    # --------------------------------------------------------- activation
    def __enter__(self) -> "Tracer":
        global _active
        with _active_lock:
            self._prev = _active
            _active = self
        return self

    def __exit__(self, *exc: object) -> None:
        global _active
        with _active_lock:
            _active = self._prev


# ---------------------------------------------------------------- module API
def current() -> Tracer | None:
    """The active tracer, or None."""
    return _active


def enabled() -> bool:
    """True iff a tracer is active (library instrumentation keys off this)."""
    return _active is not None


@contextlib.contextmanager
def tracing(name: str = "trace") -> Iterator[Tracer]:
    """Create a Tracer and activate it for the enclosed block."""
    with Tracer(name) as t:
        yield t


def span(name: str, cat: str = "",
         **attrs: Any) -> contextlib.AbstractContextManager[Any]:
    """Span on the active tracer; a shared no-op when tracing is off."""
    t = _active
    if t is None:
        return NULL_SPAN
    return t.span(name, cat, **attrs)


def record_span(name: str, dur_s: float, **kwargs: Any) -> Span | None:
    t = _active
    if t is None:
        return None
    return t.record_span(name, dur_s, **kwargs)


def counter_add(name: str, value: float, **labels: str) -> None:
    t = _active
    if t is not None:
        t.counter_add(name, value, **labels)


def gauge_set(name: str, value: float, **labels: str) -> None:
    t = _active
    if t is not None:
        t.gauge_set(name, value, **labels)
