from .costmodel import CostModel
from .simulator import ClusterSim, StageTimes

__all__ = ["CostModel", "ClusterSim", "StageTimes"]
