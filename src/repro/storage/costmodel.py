"""Cost model for the hierarchical-cluster simulator (paper §6.1/§6.2).

Constants are the paper's own testbed measurements:

* disk read 177 MiB/s (hdparm, §6.2),
* effective inner-rack bandwidth 1090 MiB/s (iperf on the 10 GbE),
* gateway efficiency 0.953 (1 Gb/s nominal -> 953 Mb/s effective),
* GF(2^8) coding throughput 600 MiB/s — back-derived from the paper's
  RelayerEncode/Decode rows of Table 3 (252 MiB / 0.443 s ≈ 569,
  192 MiB / 0.32 s = 600; we use 600),
* overlap efficiencies: how much of the non-bottleneck stage time hides
  under the bottleneck stage.  One point each is calibrated on the paper
  (degraded read: DRC(9,5,3)@1 Gb/s = 58.0% below RS; node recovery:
  DRC(9,5,3)@1 Gb/s = 2.81x RS); the remaining six ratio points of
  §6.3/§6.4 act as held-out validation (see tests/test_simulator.py).

The framework path (TPU pods) swaps these for HBM/ICI constants — see
repro/launch and DESIGN.md §3; this module keeps the paper's numbers so
Figs. 6-8 and Table 3 are reproduced under the paper's own cost model.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class CostModel:
    disk_mib_s: float = 177.0
    inner_mib_s: float = 1090.0
    gateway_eff: float = 0.953
    gf_compute_mib_s: float = 600.0
    node_encode_speedup: float = 1.5  # single-combo NodeEncode runs hotter
    call_overhead_s: float = 1.0e-5  # per-strip per serial API chain (JNI)
    fixed_block_overhead_s: float = 0.08  # block open/commit metadata
    pipeline_stages: int = 6  # disk→enc→inner→relayer→cross→decode
    overlap_degraded: float = 0.80  # calibrated: §6.4 DRC(9,5,3)@1Gb/s
    overlap_recovery: float = 0.955  # calibrated: §6.3 DRC(9,5,3)@1Gb/s
    threads: int = 4

    def gateway_mib_s(self, gbps: float) -> float:
        return gbps * self.gateway_eff * 1e9 / 8 / 2**20
