"""Hierarchical-cluster repair simulator (paper §6: Table 3, Figs. 6-8).

Executes `RepairPlan`s against the calibrated cost model: per-stage times
are derived from the plan's exact byte movement (which subblocks each
node reads, what each relayer receives/re-encodes, what crosses the
gateway), mirroring the paper's Table-3 decomposition:

    disk read → NodeEncode → inner-rack transfer → RelayerEncode →
    cross-rack transfer → Decode.

Two operations:

* degraded read (single block): the strip pipeline hides part of the
  non-bottleneck stages behind the cross-rack transfer
  (`overlap_degraded`);
* node recovery (many stripes, rotated targets/relayers — paper §5.2
  "Parallelization"): stripes pipeline against each other, so throughput
  is governed by the per-block bottleneck stage (`overlap_recovery`).

The strip/block-size effects of Fig. 8 come from per-strip call overhead
(small strips) and pipeline-fill + thread-starvation (large strips).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro import obs

from ..core.code_base import ErasureCode
from ..core.repair import TARGET, RepairPlan
from .costmodel import CostModel

MIB = 2**20


@dataclass
class StageTimes:
    disk: float
    node_encode: float
    inner: float
    relayer_encode: float
    cross: float
    decode: float
    write: float

    def as_dict(self) -> dict[str, float]:
        # Key order IS the pipeline order; names must match obs.STAGE_NAMES
        # so simulated and measured traces share one schema.
        return {
            "disk": self.disk,
            "node_encode": self.node_encode,
            "inner": self.inner,
            "relayer_encode": self.relayer_encode,
            "cross": self.cross,
            "decode": self.decode,
            "write": self.write,
        }

    def emit_spans(self, track: str, **attrs) -> None:
        """Render the decomposition as back-to-back `repro.obs` stage spans
        (cat="stage") on `track` — no-op without an active tracer."""
        if not obs.enabled():
            return
        for name, dur in self.as_dict().items():
            obs.record_span(name, dur, cat="stage", track=track, **attrs)

    @property
    def bottleneck(self) -> str:
        d = self.as_dict()
        return max(d, key=d.get)

    @property
    def total(self) -> float:
        return sum(self.as_dict().values())

    @property
    def max_stage(self) -> float:
        return max(self.as_dict().values())


def _is_selector(matrix: np.ndarray) -> bool:
    """Repair-by-transfer rows: one unit coefficient per row, no arithmetic."""
    return all(
        np.count_nonzero(row) == 1 and row[np.nonzero(row)[0][0]] == 1
        for row in matrix
    )


def _used_cols(matrix: np.ndarray) -> int:
    return int(np.count_nonzero(matrix.any(axis=0)))


class ClusterSim:
    def __init__(self, cost: CostModel | None = None):
        self.cost = cost or CostModel()

    # ------------------------------------------------------------- stages
    def stage_times(
        self,
        code: ErasureCode,
        plan: RepairPlan,
        block_mib: float,
        gateway_gbps: float,
    ) -> StageTimes:
        c = self.cost
        alpha = plan.alpha
        sub = block_mib / alpha  # MiB per subblock unit
        rack = plan.placement.rack_of
        target_rack = rack(plan.failed)

        # disk: each participant reads the subblocks its sends actually use
        read_mib: dict[int, float] = {}
        enc_time: dict[int, float] = {}
        for s in plan.node_sends:
            used = _used_cols(s.matrix)
            read_mib[s.src] = read_mib.get(s.src, 0.0) + used * sub
            if not _is_selector(s.matrix):
                enc_time[s.src] = enc_time.get(s.src, 0.0) + (used * sub) / (
                    c.gf_compute_mib_s * c.node_encode_speedup
                )
        relayer_recv: dict[int, float] = {}
        for s in plan.node_sends:
            if s.dst != TARGET:
                relayer_recv[s.dst] = relayer_recv.get(s.dst, 0.0) + s.units * sub
        rel_time: dict[int, float] = {}
        for s in plan.relayer_sends:
            own = _used_cols(s.matrix[:, :alpha]) * sub
            read_mib[s.src] = read_mib.get(s.src, 0.0) + own
            rel_time[s.src] = (own + relayer_recv.get(s.src, 0.0)) / c.gf_compute_mib_s

        disk = max(read_mib.values(), default=0.0) / c.disk_mib_s
        node_encode = max(enc_time.values(), default=0.0)

        # inner transfers into relayers (the paper's Table-3 "inner-rack"
        # row is relayer-side; locals->target rides the same 10 GbE and
        # hides under the gateway-bound stages).  Per-rack links parallel.
        inner_by_rack: dict[int, float] = {}
        for s in plan.node_sends:
            if s.dst == TARGET:
                continue
            dst_rack = rack(s.dst)
            inner_by_rack[dst_rack] = inner_by_rack.get(dst_rack, 0.0) + s.units * sub
        inner = max(inner_by_rack.values(), default=0.0) / c.inner_mib_s

        relayer_encode = max(rel_time.values(), default=0.0)

        cross_mib = 0.0
        for s in plan.relayer_sends:
            if rack(s.src) != target_rack:
                cross_mib += s.units * sub
        for s in plan.node_sends:
            if s.dst == TARGET and rack(s.src) != target_rack:
                cross_mib += s.units * sub
        cross = cross_mib / c.gateway_mib_s(gateway_gbps)

        decode_in = sum(
            s.units for s in plan.node_sends if s.dst == TARGET
        ) + sum(s.units for s in plan.relayer_sends)
        decode = decode_in * sub / c.gf_compute_mib_s
        write = block_mib / c.disk_mib_s
        t = StageTimes(disk, node_encode, inner, relayer_encode, cross, decode, write)
        tracer = obs.current()
        if tracer is not None:
            t.emit_spans(
                track=f"sim:{tracer.next_seq()}:{code!r}",
                code=repr(code), failed=plan.failed, block_mib=block_mib,
                gateway_gbps=gateway_gbps,
            )
            traffic = plan.traffic_blocks()
            obs.counter_add("sim.bytes.inner_rack",
                            traffic["inner_rack_blocks"] * block_mib * MIB)
            obs.counter_add("sim.bytes.cross_rack",
                            traffic["cross_rack_blocks"] * block_mib * MIB)
        return t

    # ------------------------------------------------- strip-size effects
    def _strip_penalty(self, t: StageTimes, block_mib: float, strip_kib: float):
        c = self.cost
        strips = max(1.0, block_mib * 1024.0 / strip_kib)
        call = strips * c.call_overhead_s
        frac = 1.0 / strips
        fill = (c.pipeline_stages - 1) * t.max_stage * frac
        starve = 1.0 if strips >= c.threads else strips / c.threads
        return call, fill, starve

    # ---------------------------------------------------------- operations
    def degraded_read_time(
        self,
        code: ErasureCode,
        block_mib: float = 64.0,
        gateway_gbps: float = 1.0,
        strip_kib: float = 256.0,
        failed: int = 0,
    ) -> float:
        with obs.span("sim.degraded_read", cat="sim", code=repr(code),
                      block_mib=block_mib, gateway_gbps=gateway_gbps):
            plan = code.repair_plan(failed)
            t = self.stage_times(code, plan, block_mib, gateway_gbps)
            call, fill, _ = self._strip_penalty(t, block_mib, strip_kib)
            others = t.total - t.cross
            latency = (
                t.cross + (1.0 - self.cost.overlap_degraded) * others + call + fill
            )
            obs.gauge_set("sim.degraded_read_s", latency, code=repr(code),
                          gateway_gbps=str(gateway_gbps))
            return latency

    def node_recovery_throughput(
        self,
        code: ErasureCode,
        num_stripes: int = 20,
        block_mib: float = 64.0,
        gateway_gbps: float = 1.0,
        strip_kib: float = 256.0,
    ) -> float:
        """MiB/s of repaired data (paper Fig. 6 / Fig. 8)."""
        with obs.span("sim.node_recovery", cat="sim", code=repr(code),
                      num_stripes=num_stripes, block_mib=block_mib,
                      gateway_gbps=gateway_gbps, strip_kib=strip_kib):
            return self._node_recovery_throughput(
                code, num_stripes, block_mib, gateway_gbps, strip_kib
            )

    def _node_recovery_throughput(
        self, code, num_stripes, block_mib, gateway_gbps, strip_kib
    ) -> float:
        per_block = []
        for s in range(num_stripes):
            failed = s % code.n  # rotate the failed block's node per stripe
            plan = code.repair_plan(failed)
            t = self.stage_times(code, plan, block_mib, gateway_gbps)
            call, fill, starve = self._strip_penalty(t, block_mib, strip_kib)
            others = t.total - t.max_stage
            compute_scale = 1.0 / starve
            per_block.append(
                t.max_stage * compute_scale
                + (1.0 - self.cost.overlap_recovery) * others
                + call
                + fill
                + self.cost.fixed_block_overhead_s / num_stripes
            )
        total_time = float(np.sum(per_block)) + self.cost.fixed_block_overhead_s
        tput = num_stripes * block_mib / total_time
        obs.gauge_set("sim.recovery_mib_s", tput, code=repr(code),
                      gateway_gbps=str(gateway_gbps))
        return tput

    # ------------------------------------------------------------ table 3
    def table3_breakdown(
        self, code: ErasureCode, block_mib: float, gateway_gbps: float = 1.0
    ) -> dict[str, float]:
        plan = code.repair_plan(0)
        t = self.stage_times(code, plan, block_mib, gateway_gbps)
        d = t.as_dict()
        d.pop("write")
        return d
