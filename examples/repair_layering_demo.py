"""Repair layering walk-through (paper §2.2 Fig. 1 + §3.2 Fig. 2).

Reproduces the motivating example: repairing one block of a (6,3) stripe
under (a) MSR flat placement, (b) MSR hierarchical placement, (c) DRC —
showing the cross-rack bandwidth dropping 5B/3 -> 4B/3 -> B, then prints
the per-stage DoubleR workflow (NodeEncode / RelayerEncode / Decode) of
the DRC plan and the simulated recovery numbers of §6.

Finally runs the whole thing again under a `repro.obs` tracer: executes
each plan on real payload bytes (DRC family 1, DRC family 2, RS),
cross-checks the traced inner-/cross-rack byte counters against the
plan's symbolic bandwidth accounting, verifies the simulator's stage
spans match the StageTimes schema, and writes a Chrome-trace JSON you
can load in chrome://tracing.

Run:  PYTHONPATH=src python examples/repair_layering_demo.py \
          [--trace-out repair_layering_trace.json]
"""
import argparse

import numpy as np

from repro import obs
from repro.core.codes import make_code
from repro.core.repair import TARGET
from repro.storage import ClusterSim, StageTimes


def traced_section(trace_out: str) -> None:
    """Execute + simulate under a tracer; cross-check; write the trace."""
    # one code per repair-plan shape the paper deploys:
    # DRC family 1 (§4.2), DRC family 2 (§4.3, repair-by-transfer), RS.
    configs = [("DRC", 9, 6, 3), ("DRC", 9, 5, 3), ("RS", 9, 5, 3)]
    sub_bytes = 4096  # bytes per subblock unit in the real-byte execution
    rng = np.random.default_rng(0)
    sim = ClusterSim()
    with obs.tracing("repair_layering_demo") as tr:
        for fam, n, k, r in configs:
            code = make_code(fam, n, k, r)
            plan = code.repair_plan(0)
            data = rng.integers(
                0, 256, size=(code.k * code.alpha, sub_bytes), dtype=np.uint8
            )
            nodes = code.encode(data)
            before = {
                scope: tr.counter_value(f"repair.bytes.{scope}_rack")
                for scope in ("inner", "cross")
            }
            rebuilt = plan.execute({i: nodes[i] for i in plan.participants()})
            assert np.array_equal(rebuilt, nodes[0]), f"{code!r} repair wrong"
            # traced bytes must equal the plan's symbolic accounting
            symbolic = plan.traffic_blocks()
            block_bytes = code.alpha * sub_bytes
            for scope in ("inner", "cross"):
                traced = tr.counter_value(f"repair.bytes.{scope}_rack") - before[scope]
                expect = symbolic[f"{scope}_rack_blocks"] * block_bytes
                assert abs(traced - expect) < 0.5, (
                    f"{code!r} {scope}: traced {traced} != symbolic {expect}"
                )
            # simulated stage decomposition rides the same trace
            sim.stage_times(code, plan, 64.0, gateway_gbps=1.0)
            traced_cross = tr.counter_value("repair.bytes.cross_rack") - before["cross"]
            print(f"  {code!r}: rebuilt OK; traced cross-rack "
                  f"{traced_cross / 1024:.1f} KiB == symbolic "
                  f"{symbolic['cross_rack_blocks']:.3f} blocks")
        # every stage_times call must have emitted the full StageTimes schema
        schema = set(StageTimes(0, 0, 0, 0, 0, 0, 0).as_dict())
        stage_spans = tr.spans_in_cat("stage")
        got = {s.name for s in stage_spans}
        assert got == schema == set(obs.STAGE_NAMES), (got, schema)
        assert len(stage_spans) == len(schema) * len(configs)
    obs.write_chrome_trace(tr, trace_out)
    obs.write_summary(tr, trace_out.replace(".json", ".summary.json"))
    print(f"  stage spans match StageTimes schema: {sorted(schema)}")
    print(f"  wrote {trace_out} (load in chrome://tracing)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace-out", default="repair_layering_trace.json")
    args = ap.parse_args()

    print("== paper §3.2 motivating example (B = 1 block) ==")
    for fam, n, k, r in [("MSR", 6, 3, 6), ("MSR", 6, 3, 3), ("DRC", 6, 3, 3)]:
        code = make_code(fam, n, k, r)
        t = code.repair_plan(0).traffic_blocks()
        tag = f"{fam}({n},{k},{r})"
        print(f"  {tag:12s} cross-rack bandwidth = {t['cross_rack_blocks']:.3f} B")

    print("\n== DoubleR workflow for DRC(9,6,3), failed node N1 ==")
    code = make_code("DRC", 9, 6, 3)
    plan = code.repair_plan(0)
    pl = plan.placement
    for s in plan.node_sends:
        dst = "target" if s.dst == TARGET else f"relayer N{s.dst + 1}"
        kind = "raw subblocks" if np.all(
            (s.matrix.sum(1) == 1) & (s.matrix.max(1) == 1)
        ) else "encoded subblocks (NodeEncode)"
        print(f"  N{s.src + 1} (rack {pl.rack_of(s.src)}) -> {dst}: "
              f"{s.units} x B/{plan.alpha} {kind}")
    for s in plan.relayer_sends:
        print(f"  N{s.src + 1} (rack {pl.rack_of(s.src)}) == RelayerEncode ==> "
              f"target: {s.units} x B/{plan.alpha} re-encoded subblocks [cross-rack]")
    print(f"  target: Decode({plan.decode.shape[1]} units) -> block N1")

    print("\n== §6 testbed simulation (64 MiB blocks, 1 Gb/s gateway) ==")
    sim = ClusterSim()
    for fam, n, k, r in [("RS", 9, 5, 3), ("DRC", 9, 5, 3)]:
        code = make_code(fam, n, k, r)
        tput = sim.node_recovery_throughput(code, gateway_gbps=1.0)
        dr = sim.degraded_read_time(code, gateway_gbps=1.0)
        print(f"  {fam}({n},{k},{r}): recovery {tput:6.1f} MiB/s, "
              f"degraded read {dr:.2f} s")

    print("\n== stage-level trace (repro.obs) ==")
    traced_section(args.trace_out)
    print("demo OK")


if __name__ == "__main__":
    main()
