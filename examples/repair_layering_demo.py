"""Repair layering walk-through (paper §2.2 Fig. 1 + §3.2 Fig. 2).

Reproduces the motivating example: repairing one block of a (6,3) stripe
under (a) MSR flat placement, (b) MSR hierarchical placement, (c) DRC —
showing the cross-rack bandwidth dropping 5B/3 -> 4B/3 -> B, then prints
the per-stage DoubleR workflow (NodeEncode / RelayerEncode / Decode) of
the DRC plan and the simulated recovery numbers of §6.

Run:  PYTHONPATH=src python examples/repair_layering_demo.py
"""
import numpy as np

from repro.core.codes import make_code
from repro.core.repair import TARGET
from repro.storage import ClusterSim


def main():
    print("== paper §3.2 motivating example (B = 1 block) ==")
    for fam, n, k, r in [("MSR", 6, 3, 6), ("MSR", 6, 3, 3), ("DRC", 6, 3, 3)]:
        code = make_code(fam, n, k, r)
        t = code.repair_plan(0).traffic_blocks()
        tag = f"{fam}({n},{k},{r})"
        print(f"  {tag:12s} cross-rack bandwidth = {t['cross_rack_blocks']:.3f} B")

    print("\n== DoubleR workflow for DRC(9,6,3), failed node N1 ==")
    code = make_code("DRC", 9, 6, 3)
    plan = code.repair_plan(0)
    pl = plan.placement
    for s in plan.node_sends:
        dst = "target" if s.dst == TARGET else f"relayer N{s.dst + 1}"
        kind = "raw subblocks" if np.all(
            (s.matrix.sum(1) == 1) & (s.matrix.max(1) == 1)
        ) else "encoded subblocks (NodeEncode)"
        print(f"  N{s.src + 1} (rack {pl.rack_of(s.src)}) -> {dst}: "
              f"{s.units} x B/{plan.alpha} {kind}")
    for s in plan.relayer_sends:
        print(f"  N{s.src + 1} (rack {pl.rack_of(s.src)}) == RelayerEncode ==> "
              f"target: {s.units} x B/{plan.alpha} re-encoded subblocks [cross-rack]")
    print(f"  target: Decode({plan.decode.shape[1]} units) -> block N1")

    print("\n== §6 testbed simulation (64 MiB blocks, 1 Gb/s gateway) ==")
    sim = ClusterSim()
    for fam, n, k, r in [("RS", 9, 5, 3), ("DRC", 9, 5, 3)]:
        code = make_code(fam, n, k, r)
        tput = sim.node_recovery_throughput(code, gateway_gbps=1.0)
        dr = sim.degraded_read_time(code, gateway_gbps=1.0)
        print(f"  {fam}({n},{k},{r}): recovery {tput:6.1f} MiB/s, "
              f"degraded read {dr:.2f} s")
    print("demo OK")


if __name__ == "__main__":
    main()
