"""Quickstart: the paper's repair layering in five minutes.

1. Encode a stripe with DRC(9,6,3) (hierarchical placement, 3 racks).
2. Kill a node; repair it with the layered plan and inspect the
   inner-rack vs cross-rack traffic (Eq. (3): 2 blocks for (9,6,3)).
3. Compare against RS and MSR on the same stripe.
4. Erasure-code a (tiny) training state and restore it with one shard
   missing — the framework-integration path.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.codes import make_code
from repro.train.checkpoint import encode_state, restore_state

import jax
import jax.numpy as jnp


def main():
    rng = np.random.default_rng(0)
    print("== 1. DRC(9,6,3): encode a stripe ==")
    code = make_code("DRC", 9, 6, 3)
    data = rng.integers(0, 256, size=(code.k * code.alpha, 1 << 16), dtype=np.uint8)
    payloads = dict(enumerate(code.encode(data)))
    print(f"  {code}: {code.n} blocks x {data.shape[1] * code.alpha / 2**10:.0f} KiB "
          f"over {code.r} racks ({code.placement.nodes_per_rack}/rack)")

    print("== 2. repair node 0 (degraded read) ==")
    plan = code.repair_plan(0)
    repaired = plan.execute({i: p for i, p in payloads.items() if i != 0})
    assert np.array_equal(repaired, payloads[0])
    t = plan.traffic_blocks()
    print(f"  exact repair OK; cross-rack={t['cross_rack_blocks']:.2f} blocks "
          f"(Eq.3 minimum), inner-rack={t['inner_rack_blocks']:.2f} blocks")
    print(f"  relayers: {plan.relayers} "
          f"(each ships {list(t['per_relayer_cross'].values())[0]:.2f} blocks)")

    print("== 3. the same repair under RS / MSR ==")
    for fam in ("RS", "MSR"):
        c = make_code(fam, 9, 6, 3)
        tt = c.repair_plan(0).traffic_blocks()
        print(f"  {c}: cross-rack={tt['cross_rack_blocks']:.2f} blocks")

    print("== 4. erasure-coded training state ==")
    state = {"w": jax.random.normal(jax.random.key(0), (256, 256), jnp.float32)}
    ckpt = encode_state(state, family="DRC", n=9, k=6, r=3)
    got, report = restore_state(ckpt, state, available=set(range(1, 9)))
    assert np.allclose(np.asarray(got["w"]), np.asarray(state["w"]))
    print(f"  restored with node 0 missing: mode={report.mode}, "
          f"cross-rack={report.cross_rack_blocks:.2f} blocks")
    print("quickstart OK")


if __name__ == "__main__":
    main()
