"""End-to-end training driver with erasure-coded fault tolerance.

Trains a ~25M-parameter minicpm-family model on the synthetic stream,
checkpoints the full training state with DRC(9,6,3) every N steps, then
*kills a checkpoint shard mid-run* and restarts from the damaged
checkpoint — the restore runs the paper's layered repair (degraded
read) and training continues bit-exactly.

Defaults are CPU-sized (~3 min).  Scale up with:
  --d-model 768 --layers 12 --steps 300      (~100M-class)

Run:  PYTHONPATH=src python examples/train_e2e.py
"""
import argparse
import dataclasses
import os
import shutil

import jax
import numpy as np

from repro.configs import get_smoke
from repro.train import (
    AdamWConfig,
    DataConfig,
    ScheduleConfig,
    SyntheticStream,
    TrainConfig,
    init_train_state,
    make_train_step,
)
from repro.train.checkpoint import CheckpointManager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_smoke("minicpm_2b"),
        name="minicpm-e2e",
        d_model=args.d_model,
        n_layers=args.layers,
        n_heads=max(4, args.d_model // 64),
        n_kv_heads=max(4, args.d_model // 64),
        d_ff=args.d_model * 3,
        vocab=8192,
    )
    tcfg = TrainConfig(
        optimizer=AdamWConfig(),
        schedule=ScheduleConfig(kind="wsd", peak_lr=1e-3,
                                total_steps=args.steps, warmup_steps=5),
    )
    params, opt, _ = init_train_state(jax.random.key(0), cfg, tcfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[e2e] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq} tokens")

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    mgr = CheckpointManager(args.ckpt_dir, family="DRC", n=9, k=6, r=3)
    stream = SyntheticStream(cfg, DataConfig(batch=args.batch, seq=args.seq))
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))

    losses = []
    crash_at = args.steps // 2
    crashed = False
    step = 0
    while step < args.steps:
        batch = stream.batch_at(step)
        params, opt, m = step_fn(params, opt, batch, step)
        losses.append(float(m["loss"]))
        if step % 10 == 0:
            print(f"[e2e] step={step:3d} loss={losses[-1]:.4f}")
        step += 1
        if step % args.ckpt_every == 0:
            mgr.save(step, {"params": params, "opt": opt})
        if step == crash_at and not crashed:
            crashed = True
            # ----- simulated node failure -----
            last = mgr.steps()[-1]
            victim = os.path.join(mgr._stepdir(last), "node_2.bin")
            os.remove(victim)
            print(f"[e2e] 💥 killed checkpoint shard node_2 of step {last}; "
                  f"restarting from damaged checkpoint")
            state = {"params": params, "opt": opt}
            state, step, report = mgr.load(state)
            params, opt = state["params"], state["opt"]
            print(f"[e2e] restored via {report.mode} "
                  f"(cross-rack={report.cross_rack_blocks:.1f} blocks); "
                  f"resuming at step {step}")
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], "loss did not improve"
    print(f"[e2e] done: loss {losses[0]:.4f} -> {losses[-1]:.4f} OK")


if __name__ == "__main__":
    main()
