"""Elastic scaling + fault tolerance demo.

1. Train a few steps; encode the state with DRC(9,6,3) (9 shards, 3 pods).
2. Lose two shards -> MDS decode.
3. *Elastically rescale* the stripe to DRC(6,4,3) (cluster shrank to 6
   failure domains) and keep training.
4. Straggler monitor steers relayer placement away from a slow pod.

Run:  PYTHONPATH=src python examples/elastic_recovery_demo.py
"""
import jax
import numpy as np

from repro.configs import get_smoke
from repro.train import DataConfig, SyntheticStream, TrainConfig, init_train_state, make_train_step
from repro.train.checkpoint import encode_state, restore_state
from repro.train.fault_tolerance import FaultToleranceManager


def main():
    cfg = get_smoke("starcoder2_3b")
    tcfg = TrainConfig()
    params, opt, _ = init_train_state(jax.random.key(0), cfg, tcfg)
    stream = SyntheticStream(cfg, DataConfig(batch=2, seq=64))
    step_fn = jax.jit(make_train_step(cfg, tcfg))

    for step in range(3):
        params, opt, m = step_fn(params, opt, stream.batch_at(step), step)
    print(f"[elastic] trained 3 steps, loss={float(m['loss']):.4f}")

    mgr = FaultToleranceManager()
    state = {"params": params, "opt": opt}
    ckpt = encode_state(state, family="DRC", n=9, k=6, r=3, step=3)
    print(f"[elastic] encoded state into DRC(9,6,3): "
          f"{sum(p.nbytes for p in ckpt.payloads.values())/2**20:.1f} MiB coded")

    lost = [1, 7]
    action = mgr.plan_recovery(ckpt, lost)
    got, report, _ = mgr.execute(ckpt, state, lost)
    print(f"[elastic] lost shards {lost}: action={action.kind}, "
          f"restore mode={report.mode} OK")

    new_ckpt = mgr.rescale(ckpt, state, n=6, k=4, r=3)
    print(f"[elastic] rescaled stripe to DRC{new_ckpt.code_spec[1:]} "
          f"(cluster shrank 9 -> 6 domains)")
    state2, rep2 = restore_state(new_ckpt, state, available={0, 1, 3, 4, 5})
    params2 = state2["params"]
    eq = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    print(f"[elastic] degraded restore from rescaled stripe: mode={rep2.mode}, "
          f"bit-exact={eq}")

    for pod in range(3):
        for _ in range(8):
            mgr.straggler.report(pod, 2.0 if pod == 1 else 1.0)
    order = mgr.straggler.preferred_relayer_order([0, 1, 2])
    print(f"[elastic] straggler mitigation: pod 1 slow -> relayer order {order}")

    params2, opt2, m = step_fn(state2["params"], state2["opt"],
                               stream.batch_at(3), 3)
    print(f"[elastic] resumed training, loss={float(m['loss']):.4f} — demo OK")


if __name__ == "__main__":
    main()
