"""Batched serving demo: prefill a batch of prompts, decode new tokens.

Uses the xlstm-125m smoke config (O(1)-per-token state) and a GQA
transformer side by side to show the unified decode-state API.

Run:  PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models import backbone
from repro.serve import ServeEngine


def run(arch: str, batch=4, prompt_len=16, gen=24):
    cfg = get_smoke(arch)
    params, _ = backbone.init_model(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, batch=batch, kv_len=prompt_len + gen + 8)
    prompts = jax.random.randint(
        jax.random.key(1), (batch, prompt_len), 0, cfg.vocab
    ).astype(jnp.int32)
    t0 = time.time()
    eng.prefill(prompts)
    t1 = time.time()
    toks = eng.generate(gen, temperature=0.8)
    t2 = time.time()
    print(f"[serve] {arch}: prefill {batch}x{prompt_len} in {t1-t0:.2f}s; "
          f"generated {batch}x{gen} tokens in {t2-t1:.2f}s "
          f"({batch*gen/(t2-t1):.0f} tok/s)")
    print(f"[serve]   sample continuation: {toks[0, :12].tolist()}")


def main():
    for arch in ("xlstm_125m", "starcoder2_3b", "zamba2_1p2b"):
        run(arch)
    print("serve demo OK")


if __name__ == "__main__":
    main()
